//! Rust mirror of `python/compile/registry.py` — the artifact matrix.
//!
//! The Python registry is the build-time source of truth: it enumerates
//! every simulated model and quantizer configuration and `aot.py` lowers
//! them to HLO artifacts plus `manifest.json`. This module mirrors that
//! registry host-side so the **native executor** can (a) reconstruct the
//! quantizer wiring an artifact simulates from its `quant` name and
//! (b) synthesize the manifest offline — `Runtime::new` works with no
//! artifacts directory at all.
//!
//! Keep the tables here in lock-step with `registry.py`; the synthesized
//! manifest must enumerate the same models, artifacts and I/O layouts the
//! AOT builder writes (`python/tests/test_manifest.py` checks the Python
//! side, `tests` below check this side).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::formats::{self, Format, IntFmt, E1M2, E2M1, E4M3, INT4, INT8};
use crate::runtime::manifest::{
    ArtifactSpec, DType, InputKind, IoSpec, Manifest, ModelCfg, ParamSpec, SiteSpec,
};
use crate::tensor::backend::Backend;

pub const VOCAB: usize = 512;
pub const CODE_VOCAB: usize = 64;
pub const SEQ: usize = 64;
pub const BATCH: usize = 8;

/// Quantized sites per transformer block (`common.py` SITE_NAMES).
pub const SITE_NAMES: [&str; 4] = ["qkv", "attn_out", "fc1", "fc2"];

/// Input dim of a site (`common.py site_in_dim`): fc2 reads the 4d FFN
/// hidden, everything else reads the d-wide residual stream.
pub fn site_in_dim(site: &str, d: usize) -> usize {
    if site == "fc2" {
        4 * d
    } else {
        d
    }
}

// --- quantizer specs -------------------------------------------------------

/// One of the paper's QDQ kinds (`quantizers.py QuantSpec.kind`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    None,
    Abfp,
    Abfp2,
    StaticInt,
    StaticIntPc,
    WPcmaxInt,
}

/// A quantize–de-quantize spec: Eqns (6)/(7)/(9) applied to one tensor
/// role while the data stays f32 (simulated quantization).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantSpec {
    pub kind: QuantKind,
    pub fmt: Option<Format>,
    /// ABFP vector length over the reduction axis.
    pub n: usize,
}

/// Second-level scale-code width used by every `abfp2` config
/// (`kernels/abfp.py` default).
pub const ABFP2_SCALE_BITS: u32 = 8;

pub const Q_NONE: QuantSpec = QuantSpec { kind: QuantKind::None, fmt: None, n: 64 };

fn abfp(fmt: Format, n: usize) -> QuantSpec {
    QuantSpec { kind: QuantKind::Abfp, fmt: Some(fmt), n }
}

fn abfp2(fmt: Format, n: usize) -> QuantSpec {
    QuantSpec { kind: QuantKind::Abfp2, fmt: Some(fmt), n }
}

fn static_int(bits: u32) -> QuantSpec {
    QuantSpec {
        kind: QuantKind::StaticInt,
        fmt: Some(Format::Int(IntFmt::new(bits))),
        n: 64,
    }
}

fn static_int_pc(bits: u32) -> QuantSpec {
    QuantSpec {
        kind: QuantKind::StaticIntPc,
        fmt: Some(Format::Int(IntFmt::new(bits))),
        n: 64,
    }
}

fn w_pcmax_int(bits: u32) -> QuantSpec {
    QuantSpec {
        kind: QuantKind::WPcmaxInt,
        fmt: Some(Format::Int(IntFmt::new(bits))),
        n: 64,
    }
}

impl QuantSpec {
    pub fn needs_runtime_scale(&self) -> bool {
        matches!(self.kind, QuantKind::StaticInt | QuantKind::StaticIntPc)
    }

    fn int_bits(&self) -> Result<u32> {
        match self.fmt {
            Some(Format::Int(f)) => Ok(f.bits),
            other => bail!("quantizer needs an integer format, got {:?}", other),
        }
    }

    /// Apply this QDQ in place to a row-major (rows, k) slice, with the
    /// bulk loops routed through `be` (see `formats::abfp_qdq_with`).
    /// `alpha` feeds the runtime clip range of the static kinds.
    pub fn apply_with(
        &self,
        x: &mut [f32],
        k: usize,
        alpha: Option<&[f32]>,
        be: &dyn Backend,
    ) -> Result<()> {
        match self.kind {
            QuantKind::None => {}
            QuantKind::Abfp => {
                let fmt = self.fmt.context("abfp needs a payload format")?;
                anyhow::ensure!(
                    self.n > 0 && k % self.n == 0,
                    "site width {} not a multiple of ABFP n={}",
                    k,
                    self.n
                );
                formats::abfp_qdq_with(x, k, fmt, self.n, be);
            }
            QuantKind::Abfp2 => {
                let fmt = self.fmt.context("abfp2 needs a payload format")?;
                anyhow::ensure!(
                    self.n > 0 && k % self.n == 0,
                    "site width {} not a multiple of ABFP n={}",
                    k,
                    self.n
                );
                formats::abfp2_qdq(x, k, fmt, self.n, ABFP2_SCALE_BITS);
            }
            QuantKind::StaticInt | QuantKind::StaticIntPc => {
                let a = alpha.context("static quantizer needs a runtime clip range")?;
                formats::static_int_qdq_with(x, a, self.int_bits()?, be);
            }
            QuantKind::WPcmaxInt => {
                formats::pcmax_weight_qdq_with(x, k, self.int_bits()?, be);
            }
        }
        Ok(())
    }

    /// Resolve this spec against a fixed row width `k` into a
    /// [`RowQdq`]: validation and scale precomputation hoisted out of
    /// the per-row hot loop, so the fused `Backend::qdq_matmul_t`
    /// A-panel prep allocates nothing per row. `alpha` feeds the
    /// runtime clip range of the static kinds, exactly as in
    /// [`QuantSpec::apply_with`].
    pub fn row_kernel(&self, k: usize, alpha: Option<&[f32]>) -> Result<RowQdq> {
        Ok(match self.kind {
            QuantKind::None => RowQdq::None,
            QuantKind::Abfp | QuantKind::Abfp2 => {
                let fmt = self.fmt.context("abfp needs a payload format")?;
                anyhow::ensure!(
                    self.n > 0 && k % self.n == 0,
                    "site width {} not a multiple of ABFP n={}",
                    k,
                    self.n
                );
                if self.kind == QuantKind::Abfp {
                    RowQdq::Abfp { fmt, n: self.n }
                } else {
                    RowQdq::Abfp2 { fmt, n: self.n }
                }
            }
            QuantKind::StaticInt | QuantKind::StaticIntPc => {
                let a = alpha.context("static quantizer needs a runtime clip range")?;
                anyhow::ensure!(
                    a.len() == 1 || a.len() == k,
                    "clip range len {} vs row width {}",
                    a.len(),
                    k
                );
                let qmax = formats::IntFmt::new(self.int_bits()?).qmax();
                let scales = a
                    .iter()
                    .map(|&v| qmax / if v > 0.0 { v } else { 1.0 })
                    .collect();
                RowQdq::StaticInt { scales, qmax }
            }
            QuantKind::WPcmaxInt => RowQdq::WPcmax { bits: self.int_bits()? },
        })
    }
}

/// A [`QuantSpec`] pre-resolved against a fixed row width: the
/// row-local QDQ kernel the fused `Backend::qdq_matmul_t` applies
/// inside its A-panel load. `apply` runs exactly the per-row math of
/// the bulk [`QuantSpec::apply_with`] path (every kernel in
/// `formats::` is row-local by construction), so fused results are
/// bit-identical to the unfused reference — the contract
/// `tests/backend_conformance.rs` enforces per backend × thread count.
#[derive(Debug, Clone)]
pub enum RowQdq {
    None,
    Abfp { fmt: Format, n: usize },
    Abfp2 { fmt: Format, n: usize },
    /// Static integer QDQ with precomputed scales: len 1 broadcasts
    /// (per-tensor clip), len k is per-channel.
    StaticInt { scales: Vec<f32>, qmax: f32 },
    WPcmax { bits: u32 },
}

impl RowQdq {
    /// In-place QDQ of one row — same bytes as the bulk path.
    pub fn apply(&self, row: &mut [f32]) {
        if row.is_empty() {
            return;
        }
        match self {
            RowQdq::None => {}
            RowQdq::Abfp { fmt, n } => formats::abfp_rows(row, row.len(), *fmt, *n),
            RowQdq::Abfp2 { fmt, n } => {
                formats::abfp2_rows(row, row.len(), *fmt, *n, ABFP2_SCALE_BITS)
            }
            RowQdq::StaticInt { scales, qmax } => {
                if scales.len() == 1 {
                    let s = scales[0];
                    for v in row.iter_mut() {
                        *v = formats::int_qdq(*v, s, *qmax);
                    }
                } else {
                    for (v, &s) in row.iter_mut().zip(scales.iter()) {
                        *v = formats::int_qdq(*v, s, *qmax);
                    }
                }
            }
            RowQdq::WPcmax { bits } => {
                let k = row.len();
                formats::pcmax_weight_qdq_with(row, k, *bits, &crate::tensor::backend::Scalar)
            }
        }
    }
}

/// How every quantized site of one artifact is wired (`common.py
/// QuantWiring`): weight / input-activation / output quantizers plus the
/// SmoothQuant and STE flags and per-layer mixed-precision overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantWiring {
    pub wq: QuantSpec,
    pub aq: QuantSpec,
    /// f_q^y; identity in all paper experiments.
    pub oq: QuantSpec,
    /// SmoothQuant per-channel input vectors are wired as inputs.
    pub smooth: bool,
    /// QAT: PWL estimator around every QDQ.
    pub ste: bool,
    /// (layer_index, wq, aq, oq); negative indices count from the back.
    pub layer_overrides: Vec<(i64, QuantSpec, QuantSpec, QuantSpec)>,
}

impl QuantWiring {
    pub fn fp32() -> QuantWiring {
        QuantWiring {
            wq: Q_NONE,
            aq: Q_NONE,
            oq: Q_NONE,
            smooth: false,
            ste: false,
            layer_overrides: Vec::new(),
        }
    }

    /// Effective wiring for block `li` of an `l`-block model
    /// (`common.py for_layer`: Python modulo semantics, so `-1` means
    /// the last block for any depth).
    pub fn for_layer(&self, li: usize, l: usize) -> QuantWiring {
        let l = l.max(1) as i64;
        for (idx, wq, aq, oq) in &self.layer_overrides {
            if idx.rem_euclid(l) == (li as i64).rem_euclid(l) {
                return QuantWiring {
                    wq: *wq,
                    aq: *aq,
                    oq: *oq,
                    smooth: self.smooth,
                    ste: self.ste,
                    layer_overrides: Vec::new(),
                };
            }
        }
        QuantWiring { layer_overrides: Vec::new(), ..self.clone() }
    }
}

/// The quantizer configuration table (`registry.py QUANT_CONFIGS`),
/// keyed by the `quant` name recorded in every artifact id.
pub fn quant_config(name: &str) -> Option<QuantWiring> {
    let base = QuantWiring::fp32();
    let sm = |wq: QuantSpec, aq: QuantSpec| QuantWiring {
        wq,
        aq,
        smooth: true,
        ..base.clone()
    };
    let smo = |wq: QuantSpec, aq: QuantSpec, oq: QuantSpec| QuantWiring {
        wq,
        aq,
        oq,
        smooth: true,
        ..base.clone()
    };
    let stat = |wq: QuantSpec, aq: QuantSpec| QuantWiring { wq, aq, ..base.clone() };
    let qat = |wq: QuantSpec, aq: QuantSpec| QuantWiring {
        wq,
        aq,
        ste: true,
        ..base.clone()
    };
    let i4 = Format::Int(INT4);
    let i8 = Format::Int(INT8);
    Some(match name {
        "fp32" => base.clone(),
        // ABFP, dynamic per-vector scales; smooth inputs allow ABFP-SQ reuse.
        "abfp_w4a4_n64" => sm(abfp(i4, 64), abfp(i4, 64)),
        "abfp_w4a4_n128" => sm(abfp(i4, 128), abfp(i4, 128)),
        "abfp_w4a8_n64" => sm(abfp(i4, 64), abfp(i8, 64)),
        "abfp_w4a8_n128" => sm(abfp(i4, 128), abfp(i8, 128)),
        "abfp_e2m1_n64" => sm(abfp(Format::Fp(E2M1), 64), abfp(Format::Fp(E2M1), 64)),
        "abfp_e1m2_n64" => sm(abfp(Format::Fp(E1M2), 64), abfp(Format::Fp(E1M2), 64)),
        "abfp_e1m2_n128" => sm(abfp(Format::Fp(E1M2), 128), abfp(Format::Fp(E1M2), 128)),
        "abfp_w4ae4m3_n64" => sm(abfp(i4, 64), abfp(Format::Fp(E4M3), 64)),
        // Static MSE calibration: per-channel max weights (in-graph),
        // runtime per-tensor activation clip ranges from the calibrator.
        "mse_w4a4" => stat(w_pcmax_int(4), static_int(4)),
        "mse_w4a8" => stat(w_pcmax_int(4), static_int(8)),
        // W8A8 static cell: the wiring the true int8 compute path
        // (`net::ComputeMode::IntKernel`) executes without simulation.
        "mse_w8a8" => stat(w_pcmax_int(8), static_int(8)),
        // RPTQ: cluster-wise activation scales expressed per-channel.
        "rptq_w4a4" => stat(w_pcmax_int(4), static_int_pc(4)),
        "rptq_w4a8" => stat(w_pcmax_int(4), static_int_pc(8)),
        // QAT (train-step artifacts only): ABFP forward, PWL backward.
        "qat_w4a4_n64" => qat(abfp(i4, 64), abfp(i4, 64)),
        "qat_w4a4_n128" => qat(abfp(i4, 128), abfp(i4, 128)),
        "qat_w4a8_n64" => qat(abfp(i4, 64), abfp(i8, 64)),
        "qat_w4a8_n128" => qat(abfp(i4, 128), abfp(i8, 128)),
        // Extensions: two-level scales (VS-Quant §II-B-2).
        "abfp2_w4a4_n64" => sm(abfp2(i4, 64), abfp2(i4, 64)),
        "abfp2_w4a8_n64" => sm(abfp2(i4, 64), abfp2(i8, 64)),
        // Extensions: output quantization f_q^y (Eqn 9).
        "abfp_w4a4_o8_n64" => smo(abfp(i4, 64), abfp(i4, 64), abfp(i8, 64)),
        "abfp_w4a4_oe4m3_n64" => {
            smo(abfp(i4, 64), abfp(i4, 64), abfp(Format::Fp(E4M3), 64))
        }
        "abfp_w4a8_o8_n64" => smo(abfp(i4, 64), abfp(i8, 64), abfp(i8, 64)),
        // Extensions: per-layer mixed precision (boundary blocks at
        // higher precision, interior at W4A4).
        "mixed_a8_boundary_n64" => QuantWiring {
            layer_overrides: vec![
                (0, abfp(i4, 64), abfp(i8, 64), Q_NONE),
                (-1, abfp(i4, 64), abfp(i8, 64), Q_NONE),
            ],
            ..sm(abfp(i4, 64), abfp(i4, 64))
        },
        "mixed_w8a8_boundary_n64" => QuantWiring {
            layer_overrides: vec![
                (0, abfp(i8, 64), abfp(i8, 64), Q_NONE),
                (-1, abfp(i8, 64), abfp(i8, 64), Q_NONE),
            ],
            ..sm(abfp(i4, 64), abfp(i4, 64))
        },
        _ => return None,
    })
}

// --- model table -----------------------------------------------------------

/// Static definition of one simulated model (`registry.py MODELS`).
#[derive(Debug, Clone, Copy)]
pub struct ModelDef {
    pub name: &'static str,
    pub arch: &'static str,
    pub task: &'static str,
    pub stands_for: &'static str,
    pub vocab: usize,
    pub d: usize,
    pub l: usize,
    pub heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub image: usize,
    pub patch: usize,
    pub channels: usize,
    pub classes: usize,
}

fn lm(name: &'static str, stands_for: &'static str, d: usize, l: usize, heads: usize) -> ModelDef {
    ModelDef {
        name,
        arch: "opt",
        task: "lm",
        stands_for,
        vocab: VOCAB,
        d,
        l,
        heads,
        seq: SEQ,
        batch: BATCH,
        image: 0,
        patch: 0,
        channels: 0,
        classes: 0,
    }
}

fn codegen(name: &'static str, stands: &'static str, d: usize, l: usize, heads: usize) -> ModelDef {
    ModelDef { vocab: CODE_VOCAB, task: "codegen", ..lm(name, stands, d, l, heads) }
}

fn bert(name: &'static str, stands: &'static str, d: usize, l: usize, heads: usize) -> ModelDef {
    ModelDef { arch: "bert", task: "span_qa", ..lm(name, stands, d, l, heads) }
}

fn vit(name: &'static str, stands_for: &'static str, patch: usize) -> ModelDef {
    ModelDef {
        name,
        arch: "vit",
        task: "image_cls",
        stands_for,
        vocab: 0,
        d: 128,
        l: 2,
        heads: 2,
        seq: 0,
        batch: 16,
        image: 32,
        patch,
        channels: 3,
        classes: 16,
    }
}

/// Every simulated model, in `registry.py` declaration order.
pub fn model_defs() -> Vec<ModelDef> {
    vec![
        lm("sim-opt-125m", "OPT 125M", 128, 2, 2),
        lm("sim-opt-350m", "OPT 350M", 256, 2, 4),
        lm("sim-opt-1.3b", "OPT 1.3B", 384, 3, 6),
        lm("sim-opt-2.7b", "OPT 2.7B", 512, 3, 8),
        codegen("sim-codegen-2b", "Codegen 2B", 256, 2, 4),
        codegen("sim-codegen-6b", "Codegen 6B", 384, 3, 6),
        bert("sim-bert-base", "BERT-base", 128, 2, 2),
        bert("sim-bert-large", "BERT-large", 256, 3, 4),
        vit("sim-vit-16", "ViT-large-16", 4),
        vit("sim-vit-32", "ViT-large-32", 8),
    ]
}

impl ModelDef {
    pub fn d_ff(&self) -> usize {
        4 * self.d
    }

    pub fn n_patches(&self) -> usize {
        if self.patch == 0 {
            0
        } else {
            (self.image / self.patch) * (self.image / self.patch)
        }
    }

    /// Per-block parameters (`common.py block_param_specs`).
    fn block_params(&self, li: usize) -> Vec<ParamSpec> {
        let d = self.d;
        let dff = self.d_ff();
        let p = |name: String, shape: Vec<usize>, init: &str| ParamSpec {
            name,
            shape,
            init: init.to_string(),
        };
        vec![
            p(format!("l{}.ln1_g", li), vec![d], "lngain"),
            p(format!("l{}.ln1_b", li), vec![d], "zeros"),
            p(format!("l{}.wqkv", li), vec![3 * d, d], "normal"),
            p(format!("l{}.bqkv", li), vec![3 * d], "zeros"),
            p(format!("l{}.wo", li), vec![d, d], "residual"),
            p(format!("l{}.bo", li), vec![d], "zeros"),
            p(format!("l{}.ln2_g", li), vec![d], "lngain"),
            p(format!("l{}.ln2_b", li), vec![d], "zeros"),
            p(format!("l{}.wfc1", li), vec![dff, d], "normal"),
            p(format!("l{}.bfc1", li), vec![dff], "zeros"),
            p(format!("l{}.wfc2", li), vec![d, dff], "residual"),
            p(format!("l{}.bfc2", li), vec![d], "zeros"),
        ]
    }

    /// Full parameter layout (`{opt,bert,vit}.py param_specs`).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let d = self.d;
        let p = |name: &str, shape: Vec<usize>, init: &str| ParamSpec {
            name: name.to_string(),
            shape,
            init: init.to_string(),
        };
        let mut specs = Vec::new();
        match self.arch {
            "vit" => {
                let pdim = self.patch * self.patch * self.channels;
                specs.push(p("patch_w", vec![d, pdim], "normal"));
                specs.push(p("patch_b", vec![d], "zeros"));
                specs.push(p("cls_tok", vec![d], "normal"));
                specs.push(p("pos_emb", vec![self.n_patches() + 1, d], "normal"));
                specs.push(p("emb_gain", vec![d], "lognormal"));
            }
            _ => {
                specs.push(p("tok_emb", vec![self.vocab, d], "normal"));
                specs.push(p("pos_emb", vec![self.seq, d], "normal"));
                specs.push(p("emb_gain", vec![d], "lognormal"));
            }
        }
        for li in 0..self.l {
            specs.extend(self.block_params(li));
        }
        specs.push(p("lnf_g", vec![d], "ones"));
        specs.push(p("lnf_b", vec![d], "zeros"));
        match self.arch {
            "bert" => {
                specs.push(p("span_w", vec![2, d], "normal"));
                specs.push(p("span_b", vec![2], "zeros"));
            }
            "vit" => {
                specs.push(p("head_w", vec![self.classes, d], "normal"));
                specs.push(p("head_b", vec![self.classes], "zeros"));
            }
            _ => {}
        }
        specs
    }

    /// Every quantized site in model order (`common.py all_site_names`).
    pub fn site_specs(&self) -> Vec<SiteSpec> {
        let mut out = Vec::with_capacity(self.l * SITE_NAMES.len());
        for li in 0..self.l {
            for s in SITE_NAMES {
                out.push(SiteSpec {
                    name: format!("l{}.{}", li, s),
                    dim: site_in_dim(s, self.d),
                });
            }
        }
        out
    }

    pub fn to_model_cfg(&self) -> ModelCfg {
        ModelCfg {
            name: self.name.to_string(),
            arch: self.arch.to_string(),
            task: self.task.to_string(),
            stands_for: self.stands_for.to_string(),
            vocab: self.vocab,
            d: self.d,
            layers: self.l,
            heads: self.heads,
            d_ff: self.d_ff(),
            seq: self.seq,
            batch: self.batch,
            image: self.image,
            patch: self.patch,
            channels: self.channels,
            classes: self.classes,
            params: self.param_specs(),
            sites: self.site_specs(),
        }
    }
}

// --- artifact enumeration --------------------------------------------------

pub const OPT_EVAL_CONFIGS: [&str; 14] = [
    "fp32",
    "abfp_w4a4_n64",
    "abfp_w4a4_n128",
    "abfp_w4a8_n64",
    "abfp_w4a8_n128",
    "abfp_e2m1_n64",
    "abfp_e1m2_n64",
    "abfp_e1m2_n128",
    "abfp_w4ae4m3_n64",
    "mse_w4a4",
    "mse_w4a8",
    "mse_w8a8",
    "rptq_w4a4",
    "rptq_w4a8",
];
pub const SMALL_EVAL_CONFIGS: [&str; 3] = ["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64"];
pub const OPT_TRAIN_CONFIGS: [&str; 5] =
    ["fp32", "qat_w4a4_n64", "qat_w4a4_n128", "qat_w4a8_n64", "qat_w4a8_n128"];
pub const ABLATION_MODELS: [&str; 2] = ["sim-opt-125m", "sim-opt-1.3b"];
pub const ABLATION_EVAL_CONFIGS: [&str; 7] = [
    "abfp2_w4a4_n64",
    "abfp2_w4a8_n64",
    "abfp_w4a4_o8_n64",
    "abfp_w4a4_oe4m3_n64",
    "abfp_w4a8_o8_n64",
    "mixed_a8_boundary_n64",
    "mixed_w8a8_boundary_n64",
];

#[derive(Debug, Clone, Copy)]
pub struct ArtifactDef {
    pub model: &'static str,
    pub purpose: &'static str,
    pub quant: &'static str,
}

impl ArtifactDef {
    pub fn id(&self) -> String {
        format!("{}/{}_{}", self.model, self.purpose, self.quant)
    }
}

/// The full artifact matrix (`registry.py artifact_defs`).
pub fn artifact_defs() -> Vec<ArtifactDef> {
    let mut defs = Vec::new();
    for m in model_defs() {
        let push = |defs: &mut Vec<ArtifactDef>, purpose: &'static str, quant: &'static str| {
            defs.push(ArtifactDef { model: m.name, purpose, quant });
        };
        match m.task {
            "lm" => {
                for q in OPT_EVAL_CONFIGS {
                    push(&mut defs, "eval", q);
                }
                if ABLATION_MODELS.contains(&m.name) {
                    for q in ABLATION_EVAL_CONFIGS {
                        push(&mut defs, "eval", q);
                    }
                }
                push(&mut defs, "capture", "fp32");
                for q in OPT_TRAIN_CONFIGS {
                    push(&mut defs, "train", q);
                }
            }
            "codegen" => {
                for q in SMALL_EVAL_CONFIGS {
                    push(&mut defs, "eval_logits", q);
                }
                push(&mut defs, "train", "fp32");
            }
            "span_qa" | "image_cls" => {
                for q in SMALL_EVAL_CONFIGS {
                    push(&mut defs, "eval", q);
                }
                push(&mut defs, "train", "fp32");
            }
            other => unreachable!("unknown task {}", other),
        }
    }
    defs
}

// --- manifest synthesis ----------------------------------------------------

fn f32_io(kind: InputKind, name: String, shape: Vec<usize>) -> IoSpec {
    IoSpec { name, kind, shape, dtype: DType::F32 }
}

fn i32_io(name: &str, shape: Vec<usize>) -> IoSpec {
    IoSpec { name: name.to_string(), kind: InputKind::Data, shape, dtype: DType::I32 }
}

fn out_io(name: &str, shape: Vec<usize>) -> IoSpec {
    IoSpec { name: name.to_string(), kind: InputKind::Data, shape, dtype: DType::F32 }
}

/// Data tensors of one artifact (`aot.py data_inputs`).
fn data_inputs(cfg: &ModelCfg, purpose: &str) -> Vec<IoSpec> {
    if cfg.arch == "vit" {
        let img = f32_io(
            InputKind::Data,
            "images".to_string(),
            vec![cfg.batch, cfg.image, cfg.image, cfg.channels],
        );
        if purpose == "train" {
            return vec![img, i32_io("labels", vec![cfg.batch])];
        }
        return vec![img];
    }
    let toks = i32_io("tokens", vec![cfg.batch, cfg.seq]);
    if cfg.arch == "bert" && purpose == "train" {
        return vec![
            toks,
            i32_io("starts", vec![cfg.batch]),
            i32_io("ends", vec![cfg.batch]),
        ];
    }
    vec![toks]
}

/// Smoothing vectors and static clip ranges (`aot.py quant_inputs`).
fn quant_inputs(cfg: &ModelCfg, wiring: &QuantWiring) -> Vec<IoSpec> {
    let mut out = Vec::new();
    if wiring.smooth {
        for s in &cfg.sites {
            out.push(f32_io(
                InputKind::Smooth,
                format!("smooth.{}", s.name),
                vec![s.dim],
            ));
        }
    }
    match wiring.aq.kind {
        QuantKind::StaticInt => {
            for s in &cfg.sites {
                out.push(f32_io(InputKind::AScale, format!("alpha.{}", s.name), vec![]));
            }
        }
        QuantKind::StaticIntPc => {
            for s in &cfg.sites {
                out.push(f32_io(
                    InputKind::AScale,
                    format!("alpha.{}", s.name),
                    vec![s.dim],
                ));
            }
        }
        _ => {}
    }
    out
}

fn artifact_spec(adef: &ArtifactDef, cfg: &ModelCfg) -> Result<ArtifactSpec> {
    let wiring = quant_config(adef.quant)
        .with_context(|| format!("unknown quant config {:?}", adef.quant))?;
    let params: Vec<IoSpec> = cfg
        .params
        .iter()
        .map(|p| f32_io(InputKind::Param, p.name.clone(), p.shape.clone()))
        .collect();
    let (b, s) = (cfg.batch, cfg.seq);

    let (inputs, outputs) = match adef.purpose {
        "eval" | "eval_logits" => {
            let mut inputs = params;
            inputs.extend(quant_inputs(cfg, &wiring));
            inputs.extend(data_inputs(cfg, adef.purpose));
            let outputs = if cfg.arch == "opt" && adef.purpose == "eval" && cfg.task != "codegen"
            {
                vec![out_io("nll_sum", vec![])]
            } else if cfg.arch == "opt" {
                vec![out_io("logits", vec![b, s, cfg.vocab])]
            } else if cfg.arch == "bert" {
                vec![out_io("start_logits", vec![b, s]), out_io("end_logits", vec![b, s])]
            } else {
                vec![out_io("logits", vec![b, cfg.classes])]
            };
            (inputs, outputs)
        }
        "capture" => {
            let mut inputs = params;
            inputs.extend(data_inputs(cfg, adef.purpose));
            let ntok = if cfg.arch == "vit" {
                let np = (cfg.image / cfg.patch) * (cfg.image / cfg.patch);
                b * (np + 1)
            } else {
                b * s
            };
            let mut outputs: Vec<IoSpec> = cfg
                .sites
                .iter()
                .map(|site| out_io(&site.name, vec![ntok, site.dim]))
                .collect();
            outputs.push(out_io("_anchor", vec![]));
            (inputs, outputs)
        }
        "train" => {
            let mut inputs = params;
            for p in &cfg.params {
                inputs.push(f32_io(InputKind::AdamM, format!("m.{}", p.name), p.shape.clone()));
            }
            for p in &cfg.params {
                inputs.push(f32_io(InputKind::AdamV, format!("v.{}", p.name), p.shape.clone()));
            }
            inputs.push(f32_io(InputKind::Scalar, "step".to_string(), vec![]));
            inputs.push(f32_io(InputKind::Scalar, "lr".to_string(), vec![]));
            inputs.extend(data_inputs(cfg, adef.purpose));
            let mut outputs = Vec::with_capacity(3 * cfg.params.len() + 1);
            for prefix in ["p", "m", "v"] {
                for p in &cfg.params {
                    outputs.push(out_io(&format!("{}.{}", prefix, p.name), p.shape.clone()));
                }
            }
            outputs.push(out_io("loss", vec![]));
            (inputs, outputs)
        }
        other => bail!("unknown artifact purpose {:?}", other),
    };

    Ok(ArtifactSpec {
        id: adef.id(),
        file: format!("{}/{}_{}.hlo.txt", adef.model, adef.purpose, adef.quant),
        model: adef.model.to_string(),
        purpose: adef.purpose.to_string(),
        quant: adef.quant.to_string(),
        inputs,
        outputs,
    })
}

/// Build the full manifest offline — same models, artifacts and I/O
/// layouts `aot.py` writes to `manifest.json`, minus the HLO files.
pub fn synthesize_manifest() -> Manifest {
    let mut models = BTreeMap::new();
    for def in model_defs() {
        models.insert(def.name.to_string(), def.to_model_cfg());
    }
    let mut artifacts = BTreeMap::new();
    for adef in artifact_defs() {
        let cfg = &models[adef.model];
        let spec = artifact_spec(&adef, cfg)
            .unwrap_or_else(|e| panic!("synthesize {}: {:#}", adef.id(), e));
        artifacts.insert(spec.id.clone(), spec);
    }
    Manifest { models, artifacts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::InputKind;

    #[test]
    fn model_table_matches_python_registry() {
        let defs = model_defs();
        assert_eq!(defs.len(), 10);
        let opt = &defs[0];
        assert_eq!(opt.name, "sim-opt-125m");
        assert_eq!((opt.vocab, opt.d, opt.l, opt.heads), (512, 128, 2, 2));
        assert_eq!(opt.d_ff(), 512);
        let v16 = defs.iter().find(|d| d.name == "sim-vit-16").unwrap();
        assert_eq!(v16.n_patches(), 64);
        let v32 = defs.iter().find(|d| d.name == "sim-vit-32").unwrap();
        assert_eq!(v32.n_patches(), 16);
        // sites: L blocks x 4, fc2 reads the FFN hidden
        let sites = opt.site_specs();
        assert_eq!(sites.len(), 8);
        assert_eq!(sites[0].name, "l0.qkv");
        assert_eq!(sites[0].dim, 128);
        assert_eq!(sites[3].name, "l0.fc2");
        assert_eq!(sites[3].dim, 512);
    }

    #[test]
    fn quant_config_table_complete() {
        for q in OPT_EVAL_CONFIGS
            .iter()
            .chain(SMALL_EVAL_CONFIGS.iter())
            .chain(OPT_TRAIN_CONFIGS.iter())
            .chain(ABLATION_EVAL_CONFIGS.iter())
            .copied()
        {
            assert!(quant_config(q).is_some(), "missing quant config {}", q);
        }
        assert!(quant_config("nope").is_none());
        let w = quant_config("abfp_w4a8_n64").unwrap();
        assert!(w.smooth && !w.ste);
        assert_eq!(w.aq.kind, QuantKind::Abfp);
        let qat = quant_config("qat_w4a4_n64").unwrap();
        assert!(qat.ste && !qat.smooth);
        let mse = quant_config("mse_w4a8").unwrap();
        assert!(mse.aq.needs_runtime_scale());
        assert_eq!(mse.wq.kind, QuantKind::WPcmaxInt);
    }

    #[test]
    fn row_kernel_matches_bulk_apply_with() {
        // The fused A-panel prep (RowQdq) must reproduce the bulk
        // QuantSpec::apply_with bytes exactly, for every quantizer kind
        // the wiring tables use.
        use crate::tensor::backend::Scalar;
        let mut rng = crate::util::rng::Pcg64::new(0x50);
        let (rows, k) = (6usize, 128usize);
        let base = crate::util::prop::heavy_vec(&mut rng, rows * k, 2.0);
        let alpha_pc: Vec<f32> = (0..k).map(|j| 0.2 + (j % 5) as f32).collect();
        let cases: Vec<(QuantSpec, Option<Vec<f32>>)> = vec![
            (abfp(Format::Int(INT4), 64), None),
            (abfp(Format::Fp(E4M3), 64), None),
            (abfp2(Format::Int(INT4), 64), None),
            (static_int(8), Some(vec![2.5])),
            (static_int_pc(4), Some(alpha_pc)),
            (w_pcmax_int(4), None),
            (Q_NONE, None),
        ];
        for (spec, alpha) in cases {
            let mut want = base.clone();
            spec.apply_with(&mut want, k, alpha.as_deref(), &Scalar).unwrap();
            let kern = spec.row_kernel(k, alpha.as_deref()).unwrap();
            let mut got = base.clone();
            for row in got.chunks_mut(k) {
                kern.apply(row);
            }
            for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    g.to_bits() == w.to_bits(),
                    "{:?} idx {}: {} vs {}",
                    spec.kind,
                    i,
                    g,
                    w
                );
            }
        }
        // invalid resolutions fail loudly, like the bulk path
        assert!(abfp(Format::Int(INT4), 64).row_kernel(100, None).is_err());
        assert!(static_int(8).row_kernel(128, None).is_err());
        assert!(static_int(8).row_kernel(128, Some(&[1.0, 2.0])).is_err());
    }

    #[test]
    fn layer_overrides_use_python_modulo() {
        let w = quant_config("mixed_a8_boundary_n64").unwrap();
        // L = 2: both blocks are boundary blocks
        for li in 0..2 {
            let lw = w.for_layer(li, 2);
            assert_eq!(lw.aq.fmt, Some(Format::Int(INT8)), "li={}", li);
            assert!(lw.smooth, "overrides inherit the parent smooth flag");
        }
        // L = 3: the middle block keeps the base W4A4 wiring
        let mid = w.for_layer(1, 3);
        assert_eq!(mid.aq.fmt, Some(Format::Int(INT4)));
        assert_eq!(w.for_layer(2, 3).aq.fmt, Some(Format::Int(INT8)));
    }

    #[test]
    fn synthesized_manifest_mirrors_real_invariants() {
        // The same invariants `manifest.rs real_manifest_invariants`
        // checks against the AOT-built manifest.json.
        let man = synthesize_manifest();
        assert_eq!(man.models.len(), 10);
        for (id, a) in &man.artifacts {
            assert_eq!(*id, format!("{}/{}_{}", a.model, a.purpose, a.quant));
            assert!(man.models.contains_key(&a.model), "{}", id);
            let m = &man.models[&a.model];
            let pnames: Vec<&str> = a
                .inputs
                .iter()
                .filter(|i| i.kind == InputKind::Param)
                .map(|i| i.name.as_str())
                .collect();
            assert_eq!(pnames.len(), m.params.len(), "{}", id);
            for (pi, ps) in pnames.iter().zip(&m.params) {
                assert_eq!(*pi, ps.name, "{}", id);
            }
            assert!(!a.outputs.is_empty(), "{}", id);
        }
        for q in ["abfp2_w4a4_n64", "mixed_a8_boundary_n64", "abfp_w4a4_o8_n64"] {
            assert!(
                man.artifacts.contains_key(&format!("sim-opt-125m/eval_{}", q)),
                "{}",
                q
            );
        }
        // train artifact layout: P params, P adam_m, P adam_v, 2 scalars,
        // then data — the exact contract train::run_training asserts.
        let t = man.artifact("sim-opt-125m/train_fp32").unwrap();
        let p = man.model("sim-opt-125m").unwrap().params.len();
        assert_eq!(t.inputs.len(), 3 * p + 2 + 1);
        assert_eq!(t.inputs[p].kind, InputKind::AdamM);
        assert_eq!(t.inputs[3 * p].kind, InputKind::Scalar);
        assert_eq!(t.inputs[3 * p + 2].kind, InputKind::Data);
        assert_eq!(t.outputs.len(), 3 * p + 1);
        // capture rows cover the calibration token count
        let c = man.artifact("sim-opt-125m/capture_fp32").unwrap();
        assert_eq!(c.outputs.last().unwrap().name, "_anchor");
        assert_eq!(c.outputs[0].shape, vec![8 * 64, 128]);
        // smooth + alpha inputs for the static configs
        let e = man.artifact("sim-opt-125m/eval_mse_w4a8").unwrap();
        assert!(e.inputs.iter().any(|i| i.name == "alpha.l0.qkv" && i.shape.is_empty()));
        assert!(!e.inputs.iter().any(|i| i.name.starts_with("smooth.")));
        let r = man.artifact("sim-opt-125m/eval_rptq_w4a4").unwrap();
        assert!(e.inputs.len() < r.inputs.len());
        assert!(r
            .inputs
            .iter()
            .any(|i| i.name == "alpha.l0.fc2" && i.shape == vec![512]));
    }
}
