//! PJRT executor: load AOT artifacts (HLO text), compile once, execute
//! with device-resident sticky inputs.
//!
//! Pattern (per /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! Compiled executables are cached per artifact id; a session binds the
//! inputs that stay fixed across calls (weights, smoothing vectors,
//! calibrated scales) as device buffers so the per-batch work is just
//! "upload tokens, execute, fetch outputs".
//!
//! Under the vendored `xla` stub every execution reports "PJRT
//! unavailable"; swap in real bindings (rust/Cargo.toml) to use this
//! path. The native executor (`super::native`) is the default.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::executor::{ExecSession, Executor};
use super::manifest::{ArtifactSpec, Manifest};
use super::Val;
use crate::info;
use crate::tensor::Tensor;

pub struct Pjrt {
    client: Rc<xla::PjRtClient>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub compile_count: RefCell<usize>,
}

impl Pjrt {
    pub fn new() -> Result<Pjrt> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Pjrt {
            client: Rc::new(client),
            cache: RefCell::new(HashMap::new()),
            compile_count: RefCell::new(0),
        })
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    fn executable(&self, dir: &Path, spec: &ArtifactSpec) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&spec.id) {
            return Ok(exe.clone());
        }
        let path = dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .with_context(|| format!("parse HLO text {:?}", path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile artifact {}", spec.id))?,
        );
        *self.compile_count.borrow_mut() += 1;
        info!("compiled {} in {:.2}s", spec.id, t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(spec.id.clone(), exe.clone());
        Ok(exe)
    }
}

fn upload(client: &xla::PjRtClient, val: &Val) -> Result<xla::PjRtBuffer> {
    match val {
        Val::F32(data, shape) => client
            .buffer_from_host_buffer::<f32>(data, shape, None)
            .context("upload f32 buffer"),
        Val::I32(data, shape) => client
            .buffer_from_host_buffer::<i32>(data, shape, None)
            .context("upload i32 buffer"),
    }
}

impl Executor for Pjrt {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn offline(&self) -> bool {
        false
    }

    fn open(
        &self,
        dir: &Path,
        _manifest: &Manifest,
        spec: &ArtifactSpec,
        sticky: &BTreeMap<String, Val>,
    ) -> Result<Box<dyn ExecSession>> {
        let exe = self.executable(dir, spec)?;
        let mut bound: Vec<Option<xla::PjRtBuffer>> = Vec::with_capacity(spec.inputs.len());
        for input in &spec.inputs {
            match sticky.get(&input.name) {
                Some(v) => bound.push(Some(upload(&self.client, v)?)),
                None => bound.push(None),
            }
        }
        Ok(Box::new(PjrtSession {
            client: self.client.clone(),
            exe,
            spec: spec.clone(),
            bound,
        }))
    }
}

/// A compiled artifact with its sticky inputs resident on device.
///
/// `ExecSession::run_batch` keeps the trait's sequential default here:
/// the compiled HLO has a fixed batch dimension, so PJRT cannot widen a
/// forward the way the native executor does — micro-batches simply
/// replay `run` per request (same results, no coalescing win until
/// batch-polymorphic artifacts are built).
struct PjrtSession {
    client: Rc<xla::PjRtClient>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    spec: ArtifactSpec,
    bound: Vec<Option<xla::PjRtBuffer>>,
}

impl ExecSession for PjrtSession {
    fn run(&self, free: &[&Val]) -> Result<Vec<Tensor>> {
        // Upload ephemerals, then assemble the full positional arg list.
        let mut ephemeral: Vec<xla::PjRtBuffer> = Vec::with_capacity(free.len());
        for v in free {
            ephemeral.push(upload(&self.client, v)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.spec.inputs.len());
        let mut e = 0;
        for b in &self.bound {
            match b {
                Some(buf) => args.push(buf),
                None => {
                    args.push(&ephemeral[e]);
                    e += 1;
                }
            }
        }
        let result = self
            .exe
            .execute_b(&args)
            .with_context(|| format!("execute {}", self.spec.id))?;
        // return_tuple=True => single tuple output; decompose to parts.
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = lit.to_tuple().context("decompose result tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: {} outputs, manifest says {}",
                self.spec.id,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, ospec) in parts.iter().zip(self.spec.outputs.iter()) {
            let data = part
                .to_vec::<f32>()
                .with_context(|| format!("output {} to f32", ospec.name))?;
            out.push(Tensor::new(ospec.shape.clone(), data));
        }
        Ok(out)
    }

    fn rebind(&mut self, i: usize, v: &Val) -> Result<()> {
        if self.bound[i].is_none() {
            bail!(
                "artifact {}: input {} is free, not sticky — cannot rebind",
                self.spec.id,
                self.spec.inputs[i].name
            );
        }
        self.bound[i] = Some(upload(&self.client, v)?);
        Ok(())
    }
}
