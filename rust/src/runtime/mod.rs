//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute
//! from the Rust hot path with device-resident sticky inputs.
//!
//! Pattern (per /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! Compiled executables are cached per artifact id; a [`Session`] binds
//! the inputs that stay fixed across calls (weights, smoothing vectors,
//! calibrated scales) as device buffers so the per-batch work is just
//! "upload tokens, execute, fetch outputs".

pub mod manifest;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::info;
use crate::tensor::Tensor;
use manifest::{ArtifactSpec, DType, Manifest};

/// A host-side input value.
#[derive(Debug, Clone)]
pub enum Val {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Val {
    pub fn scalar(v: f32) -> Val {
        Val::F32(vec![v], vec![])
    }

    pub fn from_tensor(t: &Tensor) -> Val {
        Val::F32(t.data.clone(), t.shape.clone())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Val::F32(_, s) | Val::I32(_, s) => s,
        }
    }
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub compile_count: RefCell<usize>,
}

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let dir = PathBuf::from(artifacts_dir);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: RefCell::new(HashMap::new()),
            compile_count: RefCell::new(0),
        })
    }

    /// Compile (or fetch from cache) the executable for an artifact id.
    pub fn executable(&self, id: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(id) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(id)?;
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .with_context(|| format!("parse HLO text {:?}", path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile artifact {}", id))?,
        );
        *self.compile_count.borrow_mut() += 1;
        info!("compiled {} in {:.2}s", id, t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(id.to_string(), exe.clone());
        Ok(exe)
    }

    fn upload(&self, val: &Val) -> Result<xla::PjRtBuffer> {
        match val {
            Val::F32(data, shape) => self
                .client
                .buffer_from_host_buffer::<f32>(data, shape, None)
                .context("upload f32 buffer"),
            Val::I32(data, shape) => self
                .client
                .buffer_from_host_buffer::<i32>(data, shape, None)
                .context("upload i32 buffer"),
        }
    }

    /// Open a session binding `sticky` inputs (by manifest input name).
    /// Inputs not in `sticky` must be provided per call.
    pub fn session(&self, id: &str, sticky: &BTreeMap<String, Val>) -> Result<Session<'_>> {
        let exe = self.executable(id)?;
        let spec = self.manifest.artifact(id)?.clone();
        let mut bound: Vec<Option<xla::PjRtBuffer>> = Vec::new();
        let mut free_idx = Vec::new();
        for (i, input) in spec.inputs.iter().enumerate() {
            if let Some(v) = sticky.get(&input.name) {
                check_shape(&spec, i, v)?;
                bound.push(Some(self.upload(v)?));
            } else {
                bound.push(None);
                free_idx.push(i);
            }
        }
        Ok(Session { rt: self, exe, spec, bound, free_idx })
    }
}

fn check_shape(spec: &ArtifactSpec, i: usize, v: &Val) -> Result<()> {
    let want = &spec.inputs[i].shape;
    if v.shape() != want.as_slice() {
        bail!(
            "artifact {} input {} ({}): shape {:?} != manifest {:?}",
            spec.id,
            i,
            spec.inputs[i].name,
            v.shape(),
            want
        );
    }
    let want_dtype = spec.inputs[i].dtype;
    let got_dtype = match v {
        Val::F32(..) => DType::F32,
        Val::I32(..) => DType::I32,
    };
    if want_dtype != got_dtype {
        bail!(
            "artifact {} input {} ({}): dtype mismatch",
            spec.id,
            i,
            spec.inputs[i].name
        );
    }
    Ok(())
}

/// A compiled artifact with its sticky inputs resident on device.
pub struct Session<'r> {
    rt: &'r Runtime,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub spec: ArtifactSpec,
    bound: Vec<Option<xla::PjRtBuffer>>,
    free_idx: Vec<usize>,
}

impl<'r> Session<'r> {
    /// Re-bind one sticky input (e.g. swap transformed weights in place).
    pub fn rebind(&mut self, name: &str, v: &Val) -> Result<()> {
        let i = self
            .spec
            .inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("no input named {}", name))?;
        check_shape(&self.spec, i, v)?;
        self.bound[i] = Some(self.rt.upload(v)?);
        Ok(())
    }

    /// Names of the inputs that must be supplied per call, in order.
    pub fn free_inputs(&self) -> Vec<&str> {
        self.free_idx.iter().map(|&i| self.spec.inputs[i].name.as_str()).collect()
    }

    /// Execute with per-call values for the free inputs (in free-input
    /// order). Returns one host tensor per manifest output.
    pub fn run(&self, free: &[Val]) -> Result<Vec<Tensor>> {
        if free.len() != self.free_idx.len() {
            bail!(
                "artifact {}: expected {} free inputs ({:?}), got {}",
                self.spec.id,
                self.free_idx.len(),
                self.free_inputs(),
                free.len()
            );
        }
        // Upload ephemerals, then assemble the full positional arg list.
        let mut ephemeral: Vec<xla::PjRtBuffer> = Vec::with_capacity(free.len());
        for (&i, v) in self.free_idx.iter().zip(free.iter()) {
            check_shape(&self.spec, i, v)?;
            ephemeral.push(self.rt.upload(v)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.spec.inputs.len());
        let mut e = 0;
        for (i, b) in self.bound.iter().enumerate() {
            match b {
                Some(buf) => args.push(buf),
                None => {
                    let _ = i;
                    args.push(&ephemeral[e]);
                    e += 1;
                }
            }
        }
        let result = self
            .exe
            .execute_b(&args)
            .with_context(|| format!("execute {}", self.spec.id))?;
        // return_tuple=True => single tuple output; decompose to parts.
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = lit.to_tuple().context("decompose result tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: {} outputs, manifest says {}",
                self.spec.id,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, ospec) in parts.iter().zip(self.spec.outputs.iter()) {
            let data = part
                .to_vec::<f32>()
                .with_context(|| format!("output {} to f32", ospec.name))?;
            out.push(Tensor::new(ospec.shape.clone(), data));
        }
        Ok(out)
    }
}
