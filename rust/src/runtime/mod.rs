//! Artifact runtime: a [`Runtime`] loads (or synthesizes) the manifest
//! and opens [`Session`]s that execute artifacts through a pluggable
//! [`executor::Executor`]:
//!
//! * `native` (default) — reconstructs each artifact's forward (and
//!   train-step) computation host-side from the manifest + the registry
//!   mirror, with all matmuls on the active tensor backend. Needs no
//!   on-disk artifacts: when `<dir>/manifest.json` is absent it is
//!   synthesized from [`registry`].
//! * `pjrt` — the original compiled-HLO path (see [`pjrt`]); requires
//!   built artifacts and real `xla` bindings.
//!
//! Selection: `--executor native|pjrt|auto`, `INTFPQSIM_EXECUTOR`, or
//! [`executor::configure`]; `auto` resolves to `native`.
//!
//! A [`Session`] binds the inputs that stay fixed across calls (weights,
//! smoothing vectors, calibrated scales) once — uploaded to the device
//! under PJRT, converted to host tensors (weights QDQ-prepared, one
//! backend handle hoisted) under native — so the per-batch work is just
//! "hand over tokens, execute, fetch outputs".

pub mod executor;
pub mod manifest;
pub mod native;
pub mod pjrt;
pub mod registry;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use executor::{ExecSession, Executor};
use manifest::{ArtifactSpec, DType, Manifest};

/// A host-side input value.
#[derive(Debug, Clone)]
pub enum Val {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Val {
    pub fn scalar(v: f32) -> Val {
        Val::F32(vec![v], vec![])
    }

    pub fn from_tensor(t: &Tensor) -> Val {
        Val::F32(t.data.clone(), t.shape.clone())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Val::F32(_, s) | Val::I32(_, s) => s,
        }
    }
}

pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
    exec: Rc<dyn Executor>,
}

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let exec = executor::select(executor::active_name())
            .map_err(anyhow::Error::msg)
            .context("select runtime executor")?;
        let dir = PathBuf::from(artifacts_dir);
        // Offline executors synthesize the manifest when none was built;
        // a *present but broken* manifest.json still errors (a corrupt
        // build must not be silently shadowed by the synthesizer).
        let manifest = if dir.join("manifest.json").exists() || !exec.offline() {
            Manifest::load(&dir)?
        } else {
            crate::debug!(
                "no artifacts at {:?}; synthesizing manifest for the {} executor",
                dir,
                exec.name()
            );
            registry::synthesize_manifest()
        };
        Ok(Runtime { manifest, dir, exec })
    }

    /// Name of the executor this runtime dispatches to.
    pub fn executor_name(&self) -> &'static str {
        self.exec.name()
    }

    /// Open a session binding `sticky` inputs (by manifest input name).
    /// Inputs not in `sticky` must be provided per call.
    pub fn session(&self, id: &str, sticky: &BTreeMap<String, Val>) -> Result<Session> {
        let spec = self.manifest.artifact(id)?.clone();
        let mut free_idx = Vec::new();
        for (i, input) in spec.inputs.iter().enumerate() {
            match sticky.get(&input.name) {
                Some(v) => check_shape(&spec, i, v)?,
                None => free_idx.push(i),
            }
        }
        let inner = self.exec.open(&self.dir, &self.manifest, &spec, sticky)?;
        Ok(Session { spec, free_idx, inner })
    }
}

fn check_shape(spec: &ArtifactSpec, i: usize, v: &Val) -> Result<()> {
    let want = &spec.inputs[i].shape;
    if v.shape() != want.as_slice() {
        bail!(
            "artifact {} input {} ({}): shape {:?} != manifest {:?}",
            spec.id,
            i,
            spec.inputs[i].name,
            v.shape(),
            want
        );
    }
    let want_dtype = spec.inputs[i].dtype;
    let got_dtype = match v {
        Val::F32(..) => DType::F32,
        Val::I32(..) => DType::I32,
    };
    if want_dtype != got_dtype {
        bail!(
            "artifact {} input {} ({}): dtype mismatch",
            spec.id,
            i,
            spec.inputs[i].name
        );
    }
    Ok(())
}

/// An opened artifact with its sticky inputs resident (device buffers
/// under PJRT, prepared host tensors under native).
pub struct Session {
    pub spec: ArtifactSpec,
    free_idx: Vec<usize>,
    inner: Box<dyn ExecSession>,
}

impl Session {
    /// Re-bind one sticky input (e.g. swap transformed weights in place).
    pub fn rebind(&mut self, name: &str, v: &Val) -> Result<()> {
        let i = self
            .spec
            .inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("no input named {}", name))?;
        if self.free_idx.contains(&i) {
            bail!(
                "artifact {}: input {} is free, not sticky — pass it per call",
                self.spec.id,
                name
            );
        }
        check_shape(&self.spec, i, v)?;
        self.inner.rebind(i, v)
    }

    /// Names of the inputs that must be supplied per call, in order.
    pub fn free_inputs(&self) -> Vec<&str> {
        self.free_idx.iter().map(|&i| self.spec.inputs[i].name.as_str()).collect()
    }

    /// Count/shape/dtype validation of one request's free-input values —
    /// the single gate both [`Session::run`] and [`Session::run_batch`]
    /// go through, so the batched path can never accept inputs the
    /// sequential path rejects.
    fn check_free(&self, free: &[Val]) -> Result<()> {
        if free.len() != self.free_idx.len() {
            bail!(
                "artifact {}: expected {} free inputs ({:?}), got {}",
                self.spec.id,
                self.free_idx.len(),
                self.free_inputs(),
                free.len()
            );
        }
        for (&i, v) in self.free_idx.iter().zip(free.iter()) {
            check_shape(&self.spec, i, v)?;
        }
        Ok(())
    }

    /// Execute with per-call values for the free inputs (in free-input
    /// order). Returns one host tensor per manifest output.
    pub fn run(&self, free: &[Val]) -> Result<Vec<Tensor>> {
        self.check_free(free)?;
        let refs: Vec<&Val> = free.iter().collect();
        self.inner.run(&refs)
    }

    /// Execute a micro-batch of independent requests (one free-input
    /// vector per request, each validated like [`Session::run`]).
    /// Returns one output vector per request, in request order, with
    /// per-request results bit-identical to running each sequentially;
    /// executors that support it (native, for eval artifacts) coalesce
    /// the requests into a single batched forward.
    pub fn run_batch(&self, batch: &[Vec<Val>]) -> Result<Vec<Vec<Tensor>>> {
        for free in batch {
            self.check_free(free)?;
        }
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        self.inner.run_batch(batch)
    }
}
