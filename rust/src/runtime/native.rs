//! Native host executor: evaluates artifacts by reconstructing their
//! computation from the manifest, with no PJRT and no on-disk HLO.
//!
//! Each artifact id encodes `model/purpose_quant`; the model config
//! comes from the manifest, the quantizer wiring from the registry
//! mirror (`super::registry`), and the math from `model::net` — the
//! host-side reference network whose matmuls all route through the
//! active tensor backend (one handle hoisted per session).
//!
//! Supported purposes:
//! * `eval` / `eval_logits` — forward + task output (LM `nll_sum`,
//!   logits, span logits, class logits). When every non-data input is
//!   sticky (the normal case), the prepared state — params converted to
//!   tensors once, site weights QDQ-transformed once and kept in their
//!   natural (dout, din) layout (the fused `qdq_matmul_t`/`matmul_t`
//!   hot loop reads weight rows directly, so no transposed copy exists
//!   anywhere: not in the session, not per forward) — is cached across
//!   `run` calls, so the per-batch cost is just the forward pass.
//! * `capture` — FP32 forward collecting every site's raw input
//!   activations (the calibration stream).
//! * `train` — forward + hand-rolled backward + Adam step, mirroring
//!   the compiled train-step artifacts (`python/compile/train.py`):
//!   PWL straight-through QDQ gradients, frozen outlier gains, flat
//!   (params, m, v, loss) outputs.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::executor::{ExecSession, Executor};
use super::manifest::{ArtifactSpec, InputKind, Manifest, ModelCfg};
use super::registry::{self, QuantKind, QuantWiring};
use super::Val;
use crate::model::net::{self, NetInput, SiteCtx};
use crate::tensor::backend::{self, Backend};
use crate::tensor::io::TensorStore;
use crate::tensor::Tensor;

pub struct Native;

/// Cumulative count of prepared-state builds (full weight conversion +
/// QDQ transform) across every native session in the process. The
/// serving tests assert this stays flat across repeated requests for a
/// cached session — i.e. "the second request performs no re-QDQ".
static PREPARED_BUILDS: AtomicUsize = AtomicUsize::new(0);

/// Cumulative wall-clock nanoseconds spent inside successful prepared-
/// state builds (the companion gauge to [`prepared_builds`]) — the
/// serve metrics plane reports it so an operator can see what session
/// faults actually cost, not just how often they happen.
static PREPARED_NS: AtomicU64 = AtomicU64::new(0);

/// How many times any native session has (re)built its prepared sticky
/// state since process start. Monotone; compare deltas, not absolutes.
pub fn prepared_builds() -> usize {
    PREPARED_BUILDS.load(Ordering::Relaxed)
}

/// Total nanoseconds spent in prepared-state builds since process
/// start. Monotone; compare deltas, not absolutes.
pub fn prepared_build_ns() -> u64 {
    PREPARED_NS.load(Ordering::Relaxed)
}

impl Executor for Native {
    fn name(&self) -> &'static str {
        "native"
    }

    fn offline(&self) -> bool {
        true
    }

    fn open(
        &self,
        _dir: &Path,
        manifest: &Manifest,
        spec: &ArtifactSpec,
        sticky: &BTreeMap<String, Val>,
    ) -> Result<Box<dyn ExecSession>> {
        let cfg = manifest.model(&spec.model)?.clone();
        let wiring = registry::quant_config(&spec.quant).with_context(|| {
            format!("artifact {}: quant {:?} not in the registry mirror", spec.id, spec.quant)
        })?;
        let mut bound: Vec<Option<Rc<Val>>> = Vec::with_capacity(spec.inputs.len());
        for input in &spec.inputs {
            bound.push(sticky.get(&input.name).cloned().map(Rc::new));
        }
        // The prepared fast path needs every non-data input sticky; the
        // train purpose streams everything per call instead.
        let cacheable = spec
            .inputs
            .iter()
            .zip(bound.iter())
            .all(|(i, b)| i.kind == InputKind::Data || b.is_some());
        Ok(Box::new(NativeSession {
            cfg,
            spec: spec.clone(),
            wiring,
            be: backend::active(),
            bound,
            cacheable,
            prepared: RefCell::new(None),
        }))
    }
}

/// Sticky state converted once per session: full param tensors plus the
/// per-site execution contexts (QDQ-prepared natural-layout weights,
/// smoothing vectors, clip ranges). Weights are never transposed — the
/// forward consumes them row-major through the fused
/// `Backend::qdq_matmul_t` / `Backend::matmul_t` kernels.
struct Prepared {
    params: TensorStore,
    sites: BTreeMap<String, SiteCtx>,
}

struct NativeSession {
    cfg: ModelCfg,
    spec: ArtifactSpec,
    wiring: QuantWiring,
    be: Arc<dyn Backend>,
    bound: Vec<Option<Rc<Val>>>,
    cacheable: bool,
    prepared: RefCell<Option<Prepared>>,
}

fn val_f32<'a>(spec: &ArtifactSpec, i: usize, v: &'a Val) -> Result<&'a [f32]> {
    match v {
        Val::F32(data, _) => Ok(data),
        Val::I32(..) => bail!(
            "artifact {} input {}: expected f32",
            spec.id,
            spec.inputs[i].name
        ),
    }
}

fn val_i32<'a>(spec: &ArtifactSpec, i: usize, v: &'a Val) -> Result<&'a [i32]> {
    match v {
        Val::I32(data, _) => Ok(data),
        Val::F32(..) => bail!(
            "artifact {} input {}: expected i32",
            spec.id,
            spec.inputs[i].name
        ),
    }
}

impl NativeSession {
    /// Full positional argument list: sticky bindings filled in, free
    /// values taken from `free` in free-input order.
    fn assemble<'a>(&'a self, free: &[&'a Val]) -> Result<Vec<&'a Val>> {
        let mut args: Vec<&Val> = Vec::with_capacity(self.spec.inputs.len());
        let mut fi = 0;
        for (i, b) in self.bound.iter().enumerate() {
            match b {
                Some(rc) => args.push(rc.as_ref()),
                None => {
                    let v = free.get(fi).with_context(|| {
                        format!(
                            "artifact {}: missing free input {}",
                            self.spec.id, self.spec.inputs[i].name
                        )
                    })?;
                    args.push(*v);
                    fi += 1;
                }
            }
        }
        Ok(args)
    }

    /// Convert the param / smooth / alpha inputs into execution state.
    fn build_prepared(&self, args: &[&Val]) -> Result<Prepared> {
        let t0 = std::time::Instant::now();
        let mut params = TensorStore::default();
        let mut smooth: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        let mut alpha: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        for (i, input) in self.spec.inputs.iter().enumerate() {
            match input.kind {
                InputKind::Param => {
                    let data = val_f32(&self.spec, i, args[i])?;
                    params.insert(&input.name, Tensor::new(input.shape.clone(), data.to_vec()));
                }
                InputKind::Smooth => {
                    let site = input.name.trim_start_matches("smooth.").to_string();
                    smooth.insert(site, val_f32(&self.spec, i, args[i])?.to_vec());
                }
                InputKind::AScale => {
                    let site = input.name.trim_start_matches("alpha.").to_string();
                    alpha.insert(site, val_f32(&self.spec, i, args[i])?.to_vec());
                }
                _ => {}
            }
        }
        crate::model::check_params(&self.cfg, &params)?;
        PREPARED_BUILDS.fetch_add(1, Ordering::Relaxed);
        let sites = net::build_sites(
            &self.cfg,
            &self.wiring,
            &params,
            &smooth,
            &alpha,
            self.be.as_ref(),
        )?;
        // Sites whose wiring the int GEMM can execute carry both
        // representations after prep (QDQ'd f32 weights + i8 codes), so
        // the compute mode dispatches per forward with no re-prep.
        let n_int = sites.values().filter(|s| s.int.is_some()).count();
        if n_int > 0 {
            crate::debug!(
                "native prepare {}: {}/{} sites int-prepacked (compute mode {:?})",
                self.spec.id,
                n_int,
                sites.len(),
                net::compute_mode()
            );
        }
        PREPARED_NS.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(Prepared { params, sites })
    }

    /// The data input (tokens or images), as a `NetInput`.
    fn net_input<'a>(&self, args: &[&'a Val]) -> Result<(NetInput<'a>, Vec<usize>)> {
        let mut data_idx = Vec::new();
        for (i, input) in self.spec.inputs.iter().enumerate() {
            if input.kind == InputKind::Data {
                data_idx.push(i);
            }
        }
        anyhow::ensure!(!data_idx.is_empty(), "artifact {} has no data input", self.spec.id);
        let first = data_idx[0];
        let input = if self.cfg.arch == "vit" {
            NetInput::Images(val_f32(&self.spec, first, args[first])?)
        } else {
            NetInput::Tokens(val_i32(&self.spec, first, args[first])?)
        };
        Ok((input, data_idx))
    }

    /// Run `f` against the prepared execution state: cached across runs
    /// when every non-data input is sticky, rebuilt per call otherwise.
    /// (The sticky `Val`s stay resident in `bound` so `rebind` can
    /// rebuild — the prepared tensors are a second, QDQ-transformed
    /// copy, the host analog of PJRT's device upload.)
    fn with_prepared<T>(
        &self,
        args: &[&Val],
        f: impl FnOnce(&Prepared) -> Result<T>,
    ) -> Result<T> {
        if self.cacheable {
            if self.prepared.borrow().is_none() {
                let p = self.build_prepared(args)?;
                *self.prepared.borrow_mut() = Some(p);
            }
            let guard = self.prepared.borrow();
            f(guard.as_ref().unwrap())
        } else {
            let p = self.build_prepared(args)?;
            f(&p)
        }
    }

    fn run_eval(&self, args: &[&Val]) -> Result<Vec<Tensor>> {
        self.with_prepared(args, |prep| self.eval_with(prep, args))
    }

    fn eval_with(&self, prep: &Prepared, args: &[&Val]) -> Result<Vec<Tensor>> {
        let (input, _) = self.net_input(args)?;
        let fwd = net::forward(
            &self.cfg,
            &prep.params,
            &prep.sites,
            &input,
            self.be.as_ref(),
            false,
            false,
        )?;
        let tokens = match input {
            NetInput::Tokens(t) => Some(t),
            NetInput::Images(_) => None,
        };
        self.head_outputs(fwd.head, tokens)
    }

    /// Task outputs for one request given its slice of the forward head:
    /// opt eval → scalar NLL sum; opt logits → (B, S, V); bert → start/
    /// end logit pair; vit → class logits. Shared by the single-request
    /// path and the coalesced `run_batch` split, so both produce the
    /// same bytes for the same head rows.
    fn head_outputs(&self, head: Tensor, tokens: Option<&[i32]>) -> Result<Vec<Tensor>> {
        let (b, s) = (self.cfg.batch, self.cfg.seq);
        Ok(match self.cfg.arch.as_str() {
            "opt" => {
                if self.spec.purpose == "eval" && self.cfg.task != "codegen" {
                    let tokens = tokens.context("lm eval needs its token stream")?;
                    let (nll, _) = net::nll_sum_and_grad(&head, tokens, b, s, false);
                    vec![Tensor::scalar(nll as f32)]
                } else {
                    vec![head.reshape(vec![b, s, self.cfg.vocab])]
                }
            }
            "bert" => {
                // span (N, 2) → start/end logits, each (B, S)
                let n = b * s;
                let mut sl = vec![0.0f32; n];
                let mut el = vec![0.0f32; n];
                for (r, pair) in head.data.chunks(2).enumerate() {
                    sl[r] = pair[0];
                    el[r] = pair[1];
                }
                vec![Tensor::new(vec![b, s], sl), Tensor::new(vec![b, s], el)]
            }
            "vit" => vec![head],
            other => bail!("unknown arch {}", other),
        })
    }

    /// Sequential fallback of [`ExecSession::run_batch`] (also the shape
    /// every other purpose keeps).
    fn run_seq(&self, batch: &[Vec<Val>]) -> Result<Vec<Vec<Tensor>>> {
        let mut out = Vec::with_capacity(batch.len());
        for free in batch {
            let refs: Vec<&Val> = free.iter().collect();
            out.push(self.run(&refs)?);
        }
        Ok(out)
    }

    /// Coalesced eval: concatenate every request's data tensor along the
    /// batch axis, run ONE forward with `batch = B·requests` (embedding,
    /// linears and QDQ fan-out all see a single [B·T, d] stream; the
    /// per-(b, h) attention matmuls dispatch as one wave), then split the
    /// head rows back per request. Per-request results are bit-identical
    /// to sequential `run` calls: every row-wise op, dot product and
    /// softmax sees exactly the same operands in the same order.
    fn run_eval_coalesced(&self, batch: &[Vec<Val>]) -> Result<Vec<Vec<Tensor>>> {
        let nb = batch.len();
        let refs0: Vec<&Val> = batch[0].iter().collect();
        let args0 = self.assemble(&refs0)?;
        let mut bcfg = self.cfg.clone();
        bcfg.batch = self.cfg.batch * nb;
        let concat = |expect_i32: bool| -> Result<(Vec<f32>, Vec<i32>)> {
            let mut f = Vec::new();
            let mut i = Vec::new();
            for free in batch {
                match (&free[0], expect_i32) {
                    (Val::I32(d, _), true) => i.extend_from_slice(d),
                    (Val::F32(d, _), false) => f.extend_from_slice(d),
                    _ => bail!(
                        "artifact {}: mixed data dtypes in run_batch",
                        self.spec.id
                    ),
                }
            }
            Ok((f, i))
        };
        let is_vit = self.cfg.arch == "vit";
        let (fdata, idata) = concat(!is_vit)?;
        let input = if is_vit {
            NetInput::Images(&fdata)
        } else {
            NetInput::Tokens(&idata)
        };
        let fwd = self.with_prepared(&args0, |prep| {
            net::forward(
                &bcfg,
                &prep.params,
                &prep.sites,
                &input,
                self.be.as_ref(),
                false,
                false,
            )
        })?;
        let rows_per = fwd.head.shape[0] / nb;
        let cols = fwd.head.shape[1];
        let mut out = Vec::with_capacity(nb);
        for (r, free) in batch.iter().enumerate() {
            let slice = &fwd.head.data[r * rows_per * cols..(r + 1) * rows_per * cols];
            let head_r = Tensor::new(vec![rows_per, cols], slice.to_vec());
            let tokens = match &free[0] {
                Val::I32(d, _) => Some(d.as_slice()),
                Val::F32(..) => None,
            };
            out.push(self.head_outputs(head_r, tokens)?);
        }
        Ok(out)
    }

    fn run_capture(&self, args: &[&Val]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            self.wiring == QuantWiring::fp32(),
            "capture artifacts run the FP32 graph"
        );
        let (input, _) = self.net_input(args)?;
        let fwd = self.with_prepared(args, |prep| {
            net::forward(
                &self.cfg,
                &prep.params,
                &prep.sites,
                &input,
                self.be.as_ref(),
                false,
                true,
            )
        })?;
        // _anchor mirrors the graph-liveness scalar of the compiled
        // capture artifacts: mean of the head output(s).
        let anchor = {
            let len = fwd.head.len().max(1) as f64;
            let sum: f64 = fwd.head.data.iter().map(|&v| v as f64).sum();
            match self.cfg.arch.as_str() {
                // bert: mean(start_logits) + mean(end_logits); the two
                // columns have equal counts, so 2 * mean(span).
                "bert" => 2.0 * sum / len,
                _ => sum / len,
            }
        };
        let mut out: Vec<Tensor> = Vec::with_capacity(fwd.capture.len() + 1);
        for (site, ospec) in fwd.capture.into_iter().zip(self.spec.outputs.iter()) {
            anyhow::ensure!(site.0 == ospec.name, "capture order mismatch at {}", ospec.name);
            out.push(site.1);
        }
        out.push(Tensor::scalar(anchor as f32));
        Ok(out)
    }

    fn run_train(&self, args: &[&Val]) -> Result<Vec<Tensor>> {
        let cfg = &self.cfg;
        let p = cfg.params.len();
        anyhow::ensure!(
            args.len() == self.spec.inputs.len() && args.len() > 3 * p + 2,
            "artifact {}: train input layout mismatch",
            self.spec.id
        );
        // Train wirings are fp32 or ABFP-QAT: the PWL mask is all-ones
        // (quantizers.py), which is exactly what net::backward assumes.
        for spec in [&self.wiring.wq, &self.wiring.aq] {
            anyhow::ensure!(
                matches!(spec.kind, QuantKind::None | QuantKind::Abfp),
                "artifact {}: train with {:?} quantizers is not supported natively",
                self.spec.id,
                spec.kind
            );
        }
        anyhow::ensure!(
            self.wiring.oq.kind == QuantKind::None && self.wiring.layer_overrides.is_empty(),
            "artifact {}: unsupported train wiring",
            self.spec.id
        );

        let mut params = TensorStore::default();
        let mut mstore = TensorStore::default();
        let mut vstore = TensorStore::default();
        for (j, ps) in cfg.params.iter().enumerate() {
            let t = |i: usize| -> Result<Tensor> {
                Ok(Tensor::new(
                    ps.shape.clone(),
                    val_f32(&self.spec, i, args[i])?.to_vec(),
                ))
            };
            params.insert(&ps.name, t(j)?);
            mstore.insert(&ps.name, t(p + j)?);
            vstore.insert(&ps.name, t(2 * p + j)?);
        }
        let step = val_f32(&self.spec, 3 * p, args[3 * p])?[0];
        let lr = val_f32(&self.spec, 3 * p + 1, args[3 * p + 1])?[0];
        let (input, data_idx) = self.net_input(args)?;

        let sites = net::build_sites(
            cfg,
            &self.wiring,
            &params,
            &BTreeMap::new(),
            &BTreeMap::new(),
            self.be.as_ref(),
        )?;
        let fwd = net::forward(cfg, &params, &sites, &input, self.be.as_ref(), true, false)?;
        let (b, s) = (cfg.batch, cfg.seq);
        let (loss, dhead) = match cfg.arch.as_str() {
            "opt" => {
                let tokens = match &input {
                    NetInput::Tokens(t) => *t,
                    _ => unreachable!(),
                };
                net::lm_loss_and_grad(&fwd.head, tokens, b, s, true)
            }
            "bert" => {
                anyhow::ensure!(data_idx.len() == 3, "bert train needs starts/ends");
                let starts = val_i32(&self.spec, data_idx[1], args[data_idx[1]])?;
                let ends = val_i32(&self.spec, data_idx[2], args[data_idx[2]])?;
                net::bert_span_loss_and_grad(&fwd.head, b, s, starts, ends, true)
            }
            "vit" => {
                anyhow::ensure!(data_idx.len() == 2, "vit train needs labels");
                let labels = val_i32(&self.spec, data_idx[1], args[data_idx[1]])?;
                net::softmax_ce_mean(&fwd.head, labels, true)
            }
            other => bail!("unknown arch {}", other),
        };

        let tape = fwd.tape.context("train forward must tape")?;
        let mut grads = net::backward(
            cfg,
            &params,
            &sites,
            &input,
            &tape,
            &dhead.context("loss grad")?,
            self.be.as_ref(),
        )?;

        // One Adam step (frozen outlier gains get zero gradient).
        let mut out = Vec::with_capacity(3 * p + 1);
        for ps in &cfg.params {
            if crate::train::is_frozen(&ps.name) {
                let g = grads.get_mut(&ps.name).unwrap();
                for v in g.data.iter_mut() {
                    *v = 0.0;
                }
            }
            let pt = params.get_mut(&ps.name).unwrap();
            let mt = mstore.get_mut(&ps.name).unwrap();
            let vt = vstore.get_mut(&ps.name).unwrap();
            crate::train::adam_step(
                &mut pt.data,
                &mut mt.data,
                &mut vt.data,
                &grads.get(&ps.name).unwrap().data,
                step,
                lr,
            );
        }
        for mut store in [params, mstore, vstore] {
            for ps in &cfg.params {
                out.push(store.tensors.remove(&ps.name).unwrap());
            }
        }
        out.push(Tensor::scalar(loss as f32));
        Ok(out)
    }
}

impl ExecSession for NativeSession {
    fn run(&self, free: &[&Val]) -> Result<Vec<Tensor>> {
        let args = self.assemble(free)?;
        match self.spec.purpose.as_str() {
            "eval" | "eval_logits" => self.run_eval(&args),
            "capture" => self.run_capture(&args),
            "train" => self.run_train(&args),
            other => bail!(
                "artifact {}: purpose {:?} is not supported by the native executor",
                self.spec.id,
                other
            ),
        }
    }

    fn run_batch(&self, batch: &[Vec<Val>]) -> Result<Vec<Vec<Tensor>>> {
        // Coalescible: eval purposes on the prepared fast path, with
        // exactly one free (data) input per request — the shape the
        // serving layer produces. Everything else keeps the sequential
        // semantics of the trait default.
        let coalescible = matches!(self.spec.purpose.as_str(), "eval" | "eval_logits")
            && self.cacheable
            && batch.len() > 1
            && batch.iter().all(|free| free.len() == 1);
        if coalescible {
            self.run_eval_coalesced(batch)
        } else {
            self.run_seq(batch)
        }
    }

    fn rebind(&mut self, i: usize, v: &Val) -> Result<()> {
        if self.bound[i].is_none() {
            bail!(
                "artifact {}: input {} is free, not sticky — cannot rebind",
                self.spec.id,
                self.spec.inputs[i].name
            );
        }
        self.bound[i] = Some(Rc::new(v.clone()));
        *self.prepared.borrow_mut() = None;
        Ok(())
    }
}
