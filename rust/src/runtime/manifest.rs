//! Typed view of `artifacts/manifest.json` — the contract between the
//! Python AOT builder (L1/L2) and the Rust coordinator (L3).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String, // normal | residual | zeros | ones | lognormal
}

#[derive(Debug, Clone)]
pub struct SiteSpec {
    pub name: String,
    pub dim: usize,
}

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub arch: String,
    pub task: String,
    pub stands_for: String,
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub image: usize,
    pub patch: usize,
    pub channels: usize,
    pub classes: usize,
    pub params: Vec<ParamSpec>,
    pub sites: Vec<SiteSpec>,
}

impl ModelCfg {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    Param,
    Smooth,
    AScale,
    AdamM,
    AdamV,
    Scalar,
    Data,
}

impl InputKind {
    fn parse(s: &str) -> Result<InputKind> {
        Ok(match s {
            "param" => InputKind::Param,
            "smooth" => InputKind::Smooth,
            "ascale" => InputKind::AScale,
            "adam_m" => InputKind::AdamM,
            "adam_v" => InputKind::AdamV,
            "scalar" => InputKind::Scalar,
            "data" => InputKind::Data,
            other => bail!("unknown input kind {:?}", other),
        })
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub kind: InputKind,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub id: String,
    pub file: String,
    pub model: String,
    pub purpose: String,
    pub quant: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelCfg>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn shape_of(j: &Json) -> Vec<usize> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
        .unwrap_or_default()
}

fn io_spec(j: &Json, with_kind: bool) -> Result<IoSpec> {
    let name = j.get("name").and_then(Json::as_str).context("io name")?;
    let dtype = match j.get("dtype").and_then(Json::as_str).unwrap_or("f32") {
        "i32" => DType::I32,
        _ => DType::F32,
    };
    let kind = if with_kind {
        InputKind::parse(j.get("kind").and_then(Json::as_str).context("io kind")?)?
    } else {
        InputKind::Data
    };
    Ok(IoSpec {
        name: name.to_string(),
        kind,
        shape: shape_of(j.get("shape").context("io shape")?),
        dtype,
    })
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {:?} (run `make artifacts`)", path))?;
        let j = Json::parse(&text).context("parse manifest.json")?;
        if j.get("version").and_then(Json::as_usize) != Some(1) {
            bail!("unsupported manifest version");
        }

        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models").and_then(Json::as_obj).context("models")? {
            let g = |k: &str| mj.get(k).and_then(Json::as_usize).unwrap_or(0);
            let gs = |k: &str| {
                mj.get(k).and_then(Json::as_str).unwrap_or_default().to_string()
            };
            let params = mj
                .get("params")
                .and_then(Json::as_arr)
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name").and_then(Json::as_str).context("pname")?.into(),
                        shape: shape_of(p.get("shape").context("pshape")?),
                        init: p
                            .get("init")
                            .and_then(Json::as_str)
                            .unwrap_or("normal")
                            .into(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let sites = mj
                .get("sites")
                .and_then(Json::as_arr)
                .context("sites")?
                .iter()
                .map(|s| {
                    Ok(SiteSpec {
                        name: s.get("name").and_then(Json::as_str).context("sname")?.into(),
                        dim: s.get("dim").and_then(Json::as_usize).context("sdim")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelCfg {
                    name: name.clone(),
                    arch: gs("arch"),
                    task: gs("task"),
                    stands_for: gs("stands_for"),
                    vocab: g("vocab"),
                    d: g("d"),
                    layers: g("L"),
                    heads: g("heads"),
                    d_ff: g("d_ff"),
                    seq: g("seq"),
                    batch: g("batch"),
                    image: g("image"),
                    patch: g("patch"),
                    channels: g("channels"),
                    classes: g("classes"),
                    params,
                    sites,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (id, aj) in j.get("artifacts").and_then(Json::as_obj).context("artifacts")? {
            let gs = |k: &str| -> Result<String> {
                Ok(aj.get(k).and_then(Json::as_str).context("artifact str")?.to_string())
            };
            let inputs = aj
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(|i| io_spec(i, true))
                .collect::<Result<Vec<_>>>()?;
            let outputs = aj
                .get("outputs")
                .and_then(Json::as_arr)
                .context("outputs")?
                .iter()
                .map(|o| io_spec(o, false))
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                id.clone(),
                ArtifactSpec {
                    id: id.clone(),
                    file: gs("file")?,
                    model: gs("model")?,
                    purpose: gs("purpose")?,
                    quant: gs("quant")?,
                    inputs,
                    outputs,
                },
            );
        }

        Ok(Manifest { models, artifacts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelCfg> {
        self.models
            .get(name)
            .with_context(|| format!("model {:?} not in manifest", name))
    }

    pub fn artifact(&self, id: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(id)
            .with_context(|| format!("artifact {:?} not in manifest", id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn real_manifest() -> Option<Manifest> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn input_kind_parse_all_and_reject_unknown() {
        for (s, k) in [
            ("param", InputKind::Param),
            ("smooth", InputKind::Smooth),
            ("ascale", InputKind::AScale),
            ("adam_m", InputKind::AdamM),
            ("adam_v", InputKind::AdamV),
            ("scalar", InputKind::Scalar),
            ("data", InputKind::Data),
        ] {
            assert_eq!(InputKind::parse(s).unwrap(), k);
        }
        assert!(InputKind::parse("weights").is_err());
        assert!(InputKind::parse("").is_err());
    }

    #[test]
    fn load_rejects_wrong_version_and_garbage() {
        let dir = std::env::temp_dir().join(format!("ifq_mani_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // wrong version
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 2, "models": {}, "artifacts": {}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        // syntactically broken
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
        // missing file
        std::fs::remove_file(dir.join("manifest.json")).unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(format!("{:#}", err).contains("make artifacts"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_minimal_valid_manifest() {
        let dir = std::env::temp_dir().join(format!("ifq_mani_ok_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1,
                "models": {"m": {"arch": "opt", "task": "lm", "vocab": 16,
                    "d": 8, "L": 1, "heads": 2, "d_ff": 32, "seq": 4, "batch": 2,
                    "params": [{"name": "w", "shape": [3, 4], "init": "normal"}],
                    "sites": [{"name": "l0.qkv", "dim": 8}]}},
                "artifacts": {"m/eval_fp32": {"file": "m/eval_fp32.hlo.txt",
                    "model": "m", "purpose": "eval", "quant": "fp32",
                    "inputs": [{"name": "w", "kind": "param", "shape": [3, 4],
                                "dtype": "f32"}],
                    "outputs": [{"name": "nll_sum", "shape": [], "dtype": "f32"}]}}}"#,
        )
        .unwrap();
        let man = Manifest::load(&dir).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.param_count(), 12);
        assert_eq!(m.layers, 1);
        assert_eq!(m.sites[0].dim, 8);
        let a = man.artifact("m/eval_fp32").unwrap();
        assert_eq!(a.inputs[0].kind, InputKind::Param);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert!(man.model("nope").is_err());
        assert!(man.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_invariants() {
        let Some(man) = real_manifest() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        assert_eq!(man.models.len(), 10);
        for (id, a) in &man.artifacts {
            // id encodes model/purpose_quant
            assert_eq!(*id, format!("{}/{}_{}", a.model, a.purpose, a.quant));
            assert!(man.models.contains_key(&a.model), "{}", id);
            // every artifact's param inputs match the model's param table
            let m = &man.models[&a.model];
            let pnames: Vec<&str> = a
                .inputs
                .iter()
                .filter(|i| i.kind == InputKind::Param)
                .map(|i| i.name.as_str())
                .collect();
            if !pnames.is_empty() {
                assert_eq!(pnames.len(), m.params.len(), "{}", id);
                for (pi, ps) in pnames.iter().zip(&m.params) {
                    assert_eq!(*pi, ps.name, "{}", id);
                }
            }
            assert!(!a.outputs.is_empty(), "{}", id);
        }
        // the extension configs made it into the matrix
        for q in ["abfp2_w4a4_n64", "mixed_a8_boundary_n64", "abfp_w4a4_o8_n64"] {
            assert!(
                man.artifacts.contains_key(&format!("sim-opt-125m/eval_{}", q)),
                "{}",
                q
            );
        }
    }
}
