//! Runtime `Executor` seam — how a [`super::Session`] actually runs.
//!
//! Mirrors the `tensor::backend::Backend` selection pattern: a small
//! process-wide registry of named strategies, configurable at runtime:
//!
//! * env: `INTFPQSIM_EXECUTOR=native|pjrt|auto` (`auto` = `native`);
//! * CLI: `repro ... --executor native`;
//! * API: [`configure`].
//!
//! Two executors ship:
//!
//! * [`super::native::Native`] — evaluates each artifact host-side by
//!   reconstructing its forward computation from the manifest (and the
//!   Rust registry mirror), with all matmuls routed through the active
//!   tensor backend. Works fully offline: when no artifacts directory
//!   exists the manifest is synthesized.
//! * [`super::pjrt::Pjrt`] — the original PJRT path (HLO text →
//!   compile → execute). Requires built artifacts and real `xla`
//!   bindings; under the vendored stub every execution reports "PJRT
//!   unavailable".

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::{OnceLock, RwLock};

use anyhow::Result;

use super::manifest::{ArtifactSpec, Manifest};
use super::Val;
use crate::tensor::Tensor;

/// An artifact-execution strategy. `open` binds an artifact's sticky
/// inputs (weights, smoothing vectors, calibrated scales) into an
/// [`ExecSession`]; everything per-batch goes through `ExecSession::run`.
pub trait Executor {
    fn name(&self) -> &'static str;

    /// Whether this executor can run without on-disk HLO artifacts
    /// (if so, `Runtime::new` synthesizes the manifest when absent).
    fn offline(&self) -> bool;

    fn open(
        &self,
        dir: &Path,
        manifest: &Manifest,
        spec: &ArtifactSpec,
        sticky: &BTreeMap<String, Val>,
    ) -> Result<Box<dyn ExecSession>>;
}

/// One opened artifact with its sticky inputs resident (uploaded to the
/// device for PJRT, converted to host tensors once for native).
pub trait ExecSession {
    /// Execute with per-call values for the free inputs, in free-input
    /// order. Input counts/shapes are validated by the outer `Session`.
    fn run(&self, free: &[&Val]) -> Result<Vec<Tensor>>;

    /// Execute a micro-batch of independent requests, each with its own
    /// free-input values, returning one output vector per request.
    /// Semantically identical to calling [`ExecSession::run`] once per
    /// element — which is exactly what this default does. Implementations
    /// may coalesce compatible requests into one batched forward (the
    /// native executor does, for eval artifacts), but per-request results
    /// must stay bit-identical to the sequential loop; the serving layer
    /// and `tests/backend_conformance.rs` rely on it.
    fn run_batch(&self, batch: &[Vec<Val>]) -> Result<Vec<Vec<Tensor>>> {
        let mut out = Vec::with_capacity(batch.len());
        for free in batch {
            let refs: Vec<&Val> = free.iter().collect();
            out.push(self.run(&refs)?);
        }
        Ok(out)
    }

    /// Replace one sticky input (position `i` of the artifact's input
    /// list) — e.g. swap transformed weights in place. Implementations
    /// copy only if they retain the value (PJRT uploads and moves on).
    fn rebind(&mut self, i: usize, v: &Val) -> Result<()>;
}

/// Every registered executor name.
pub fn all_names() -> &'static [&'static str] {
    &["native", "pjrt"]
}

/// Resolve a user-facing name (`auto`/empty = native).
pub fn resolve(name: &str) -> Result<&'static str, String> {
    match name {
        "" | "auto" | "native" => Ok("native"),
        "pjrt" => Ok("pjrt"),
        other => Err(format!(
            "unknown executor {:?} (expected {}|auto)",
            other,
            all_names().join("|")
        )),
    }
}

fn registry() -> &'static RwLock<&'static str> {
    static ACTIVE: OnceLock<RwLock<&'static str>> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let name = std::env::var("INTFPQSIM_EXECUTOR").unwrap_or_default();
        RwLock::new(resolve(&name).unwrap_or_else(|e| {
            crate::util::logging::log(1, &format!("{}; falling back to native", e));
            "native"
        }))
    })
}

/// The executor name new `Runtime`s are built with.
pub fn active_name() -> &'static str {
    *registry().read().unwrap()
}

/// Parse-and-install, as the `--executor` CLI flag does.
pub fn configure(name: &str) -> Result<(), String> {
    let resolved = resolve(name)?;
    *registry().write().unwrap() = resolved;
    Ok(())
}

/// Construct an executor instance by name. Instances are per-`Runtime`
/// (they hold non-Send state: PJRT clients, compile caches), so unlike
/// tensor backends only the *name* is process-wide.
pub fn select(name: &str) -> Result<Rc<dyn Executor>, String> {
    Ok(match resolve(name)? {
        "native" => Rc::new(super::native::Native) as Rc<dyn Executor>,
        "pjrt" => Rc::new(super::pjrt::Pjrt::new().map_err(|e| e.to_string())?),
        other => unreachable!("{} resolves but is not constructible", other),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_names() {
        assert_eq!(resolve("auto").unwrap(), "native");
        assert_eq!(resolve("").unwrap(), "native");
        assert_eq!(resolve("native").unwrap(), "native");
        assert_eq!(resolve("pjrt").unwrap(), "pjrt");
        assert!(resolve("tpu").is_err());
        for &n in all_names() {
            assert_eq!(resolve(n).unwrap(), n);
            assert_eq!(select(n).unwrap().name(), n);
        }
    }

    #[test]
    fn configure_validates_and_installs() {
        let before = active_name();
        assert!(configure("nope").is_err());
        assert_eq!(active_name(), before, "failed configure must not switch");
        configure("pjrt").unwrap();
        assert_eq!(active_name(), "pjrt");
        configure(before).unwrap();
    }

    #[test]
    fn offline_contract() {
        assert!(select("native").unwrap().offline());
        assert!(!select("pjrt").unwrap().offline());
    }
}
