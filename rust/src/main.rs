//! `repro` — the INT-FP-QSim coordinator CLI.
//!
//! Commands:
//!   repro list [--models]             list experiments / simulated models
//!   repro pretrain --model <m>        pretrain (and cache) FP32 weights
//!   repro qat --model <m> --quant <q> QAT fine-tune from the FP32 ckpt
//!   repro eval --model <m> --quant <q> [--method sq|gptq|rptq|qat]
//!   repro calibrate --model <m>       capture + print calibration summary
//!   repro experiment --id <tableN|figN> | --all [--fast]
//!   repro report                      concatenate saved reports
//!   repro serve                       micro-batching server (stdio or TCP)
//!   repro loadgen                     closed-loop load generator
//!
//! Global options: --artifacts DIR (default artifacts), --checkpoints DIR
//! (default checkpoints), --eval-batches N, --qat-steps N, -v/--verbose,
//! --backend scalar|blocked|simd|threaded|pool|auto, --threads N (omit
//! for all cores; 0 and non-numeric values are rejected),
//! --executor native|pjrt|auto (auto = native host execution, no
//! artifacts required), --compute qdq|int (qdq = simulated
//! quantize-dequantize matmuls, the default; int = true i8×i8→i32
//! GEMM on prepacked weights for eligible static-int sites).
//!
//! Serving options (serve + loadgen): --batch-window MS (default 5),
//! --max-batch N (default 8), --queue-cap N (default 64), --workers N
//! (default 1; >1 = sharded pool), --replicate-hot, --hot-min N,
//! --drain-timeout MS (default 5000; graceful-drain budget for the
//! `shutdown` verb); serve adds --listen ADDR (TCP instead of stdio),
//! --stats-every S (log a compact metrics snapshot every S seconds),
//! --idle-timeout MS (reap TCP connections that stay silent),
//! --max-conns N (cap concurrent TCP connections; excess get one
//! `queue_full` retry-later line) and --faults SPEC (deterministic
//! fault injection, e.g. `seed=2,panic=7,delay=3:25,drop=5`; the
//! INTFPQSIM_FAULTS env var is the fallback); loadgen adds --clients N,
//! --requests N (per client), --mix model:quant[,...], --deadline-ms D,
//! --connect ADDR (drive a --listen server over TCP; --listen is
//! accepted as an alias). All counts must be positive integers — 0 or
//! junk is a hard error, never a silent default. `docs/serving.md` is
//! the full operator guide.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use intfpqsim::coordinator::{self, registry};
use intfpqsim::info;
use intfpqsim::quantsim::{EvalOpts, Method, QuantConfig, Simulator};
use intfpqsim::serve::shard::{ShardCfg, SimSpec};
use intfpqsim::serve::{self, loadgen::LoadgenCfg, ServeCfg};
use intfpqsim::train::{self, TrainOpts};
use intfpqsim::util::cli::Args;
use intfpqsim::util::logging;

const USAGE: &str =
    "usage: repro <list|pretrain|qat|eval|calibrate|experiment|report|serve|loadgen> [options]
  repro list [--models]
  repro pretrain --model sim-opt-125m [--steps 300] [--lr 3e-3]
  repro qat --model sim-opt-125m --quant qat_w4a4_n64 [--steps 60]
  repro eval --model sim-opt-125m --quant abfp_w4a4_n64 [--method none|sq|gptq|rptq|qat]
  repro calibrate --model sim-opt-125m
  repro experiment --id table1 | --all  [--fast] [--force]
  repro report
  repro serve [--listen ADDR] [--workers N] [--replicate-hot] [--hot-min N]
              [--batch-window MS] [--max-batch N] [--queue-cap N] [--fast]
              [--stats-every S] [--idle-timeout MS] [--drain-timeout MS]
              [--max-conns N] [--faults SPEC]
  repro loadgen [--connect ADDR] [--clients N] [--requests N]
                [--mix model:quant,...] [--deadline-ms D] [--workers N]
                [--replicate-hot] [--hot-min N] [--batch-window MS]
                [--max-batch N] [--queue-cap N] [--fast]
global: [--backend scalar|blocked|simd|threaded|pool|auto] [--threads N]
        [--executor native|pjrt|auto] [--compute qdq|int]";

fn main() {
    // Pin the log epoch before any work: `[  12.34s]` offsets measure
    // from launch, not from whenever something first logs.
    logging::init_epoch();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {:#}", e);
            eprintln!("{}", USAGE);
            std::process::exit(1);
        }
    }
}

/// Apply the shared `--eval-batches`/`--qat-steps`/`--fast` knobs —
/// used by both [`make_sim`] and [`make_spec`] so an in-process
/// simulator and a shard-worker recipe can never disagree.
fn apply_eval_opts(a: &Args, opts: &mut EvalOpts) {
    opts.eval_batches = a.get_u64("eval-batches", opts.eval_batches);
    opts.qat_opts.steps = a.get_usize("qat-steps", opts.qat_opts.steps);
    if a.flag("fast") {
        // reduced-fidelity mode for smoke runs and benches
        opts.eval_batches = 4;
        opts.pass1_programs = 16;
        opts.qat_opts.steps = 8;
        opts.pretrain_opts.steps = 60;
    }
}

fn make_sim(a: &Args) -> Result<Simulator> {
    let mut sim = Simulator::new(
        a.get("artifacts", "artifacts"),
        a.get("checkpoints", "checkpoints"),
    )?;
    apply_eval_opts(a, &mut sim.opts);
    Ok(sim)
}

/// The cloneable recipe shard workers rebuild their simulators from.
fn make_spec(a: &Args) -> Result<SimSpec> {
    let mut spec = SimSpec::new(
        a.get("artifacts", "artifacts"),
        a.get("checkpoints", "checkpoints"),
    );
    apply_eval_opts(a, &mut spec.opts);
    Ok(spec)
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "none" => Method::None,
        "sq" | "smoothquant" => Method::SmoothQuant,
        "gptq" => Method::Gptq,
        "rptq" => Method::Rptq,
        "qat" => Method::Qat,
        other => bail!("unknown method {:?}", other),
    })
}

fn run(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &["models", "all", "force", "fast", "verbose", "replicate-hot"])
        .map_err(|e| anyhow::anyhow!(e))?;
    if a.flag("verbose") {
        logging::set_level(2);
    }
    // Tensor execution backend for every host-side transform this
    // invocation runs (GPTQ Hessians, SmoothQuant, calibration). Only
    // explicit flags override; otherwise the INTFPQSIM_BACKEND /
    // INTFPQSIM_THREADS environment selection stays in effect.
    if a.options.contains_key("backend") || a.options.contains_key("threads") {
        // Strict: an explicit --threads must be a positive integer (omit
        // the flag for all cores) — 0 or junk is a configuration error,
        // never a silent fallback.
        let threads = a.get_usize_min("threads", 0, 1).map_err(anyhow::Error::msg)?;
        intfpqsim::tensor::backend::configure(a.get("backend", "auto"), threads)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    // Runtime executor: native host evaluation (default) or the PJRT
    // compiled-artifact path. Only explicit flags override, so the
    // INTFPQSIM_EXECUTOR environment selection stays in effect.
    if a.options.contains_key("executor") {
        intfpqsim::runtime::executor::configure(a.get("executor", "auto"))
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    // Quantized compute mode: simulated QDQ matmuls (default) or the
    // true i8×i8→i32 integer GEMM for eligible static-int sites. Only
    // explicit flags override, so the INTFPQSIM_COMPUTE environment
    // selection stays in effect. Unknown values are a hard error, like
    // --backend and --executor.
    if a.options.contains_key("compute") {
        intfpqsim::model::net::configure_compute(a.get("compute", "qdq"))
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    match a.command.as_str() {
        "list" => {
            if a.flag("models") {
                let sim = make_sim(&a)?;
                println!(
                    "{:<16} {:<12} {:<10} {:>9} {:>4} {:>5}",
                    "model", "stands for", "task", "params", "L", "d"
                );
                for (name, cfg) in &sim.rt.manifest.models {
                    println!(
                        "{:<16} {:<12} {:<10} {:>9} {:>4} {:>5}",
                        name, cfg.stands_for, cfg.task, cfg.param_count(), cfg.layers, cfg.d
                    );
                }
            } else {
                println!("{:<8} {:<10} {}", "id", "paper", "title");
                for e in registry() {
                    println!("{:<8} {:<10} {}", e.id, e.paper_ref, e.title);
                }
            }
            Ok(())
        }
        "pretrain" => {
            let sim = make_sim(&a)?;
            let model = a.get("model", "");
            anyhow::ensure!(!model.is_empty(), "--model required");
            let opts = TrainOpts {
                steps: a.get_usize("steps", 300),
                peak_lr: a.get_f32("lr", 3e-3),
                ..Default::default()
            };
            if sim.ck.exists(model, "fp32") && !a.flag("force") {
                info!("{} fp32 checkpoint already exists (use --force)", model);
                return Ok(());
            }
            if a.flag("force") {
                std::fs::remove_file(sim.ck.path(model, "fp32")).ok();
            }
            train::pretrain_cached(&sim.rt, model, &sim.ck, &opts)?;
            Ok(())
        }
        "qat" => {
            let sim = make_sim(&a)?;
            let model = a.get("model", "");
            let quant = a.get("quant", "qat_w4a4_n64");
            anyhow::ensure!(!model.is_empty(), "--model required");
            let opts = TrainOpts {
                steps: a.get_usize("steps", 60),
                peak_lr: a.get_f32("lr", 3e-4),
                warmup: 6,
                ..Default::default()
            };
            train::qat_cached(&sim.rt, model, quant, &sim.ck, &opts)?;
            Ok(())
        }
        "eval" => {
            let sim = make_sim(&a)?;
            let model = a.get("model", "");
            anyhow::ensure!(!model.is_empty(), "--model required");
            let qc = QuantConfig::with(
                a.get("quant", "fp32"),
                parse_method(a.get("method", "none"))?,
            );
            let m = sim.evaluate(model, &qc)?;
            println!("{} [{}] {} = {:.3}", model, qc.label(), m.kind.name(), m.value);
            Ok(())
        }
        "calibrate" => {
            let sim = make_sim(&a)?;
            let model = a.get("model", "");
            anyhow::ensure!(!model.is_empty(), "--model required");
            let stats = sim.calibration(model)?;
            println!(
                "{:<16} {:>10} {:>12} {:>12} {:>12}",
                "site", "rows", "absmax", "mse_a4", "mse_a8"
            );
            for (site, t) in &stats.acts {
                let a4 = intfpqsim::calib::mse_alpha(&t.data, 4);
                let a8 = intfpqsim::calib::mse_alpha(&t.data, 8);
                println!(
                    "{:<16} {:>10} {:>12.4} {:>12.4} {:>12.4}",
                    site, t.shape[0], t.absmax(), a4, a8
                );
            }
            Ok(())
        }
        "experiment" => {
            let sim = make_sim(&a)?;
            if a.flag("all") {
                for e in registry() {
                    coordinator::run_experiment(&sim, e.id)?;
                }
            } else {
                let id = a.get("id", "");
                anyhow::ensure!(!id.is_empty(), "--id or --all required");
                coordinator::run_experiment(&sim, id)?;
            }
            Ok(())
        }
        "report" => {
            let mut out = String::new();
            for e in registry() {
                let p = format!("results/{}.md", e.id);
                if let Ok(text) = std::fs::read_to_string(&p) {
                    out.push_str(&text);
                    out.push('\n');
                }
            }
            if out.is_empty() {
                bail!("no saved reports under results/ (run `repro experiment --all`)");
            }
            println!("{}", out);
            std::fs::write("results/ALL.md", &out).context("write results/ALL.md")?;
            Ok(())
        }
        "serve" => {
            let cfg = serve_cfg_from(&a)?;
            let shard = shard_cfg_from(&a)?;
            // Deterministic fault injection (chaos testing): the
            // --faults flag wins over the INTFPQSIM_FAULTS env var;
            // either being malformed is a hard startup error.
            if let Some(spec) = a.options.get("faults") {
                let plan = serve::faults::FaultPlan::parse(spec)
                    .with_context(|| format!("--faults {:?}", spec))?;
                serve::faults::install(plan);
            } else {
                serve::faults::init_from_env()?;
            }
            if a.options.contains_key("stats-every") {
                let every = a.get_u64_min("stats-every", 0, 1).map_err(anyhow::Error::msg)?;
                spawn_stats_reporter(every);
            }
            if let Some(addr) = a.options.get("listen") {
                serve::transport::run_tcp(make_spec(&a)?, addr, &cfg, &shard)
            } else if shard.workers > 1 {
                serve::run_stdio_sharded(&make_spec(&a)?, &cfg, &shard)
            } else {
                serve::run_stdio(&make_sim(&a)?, &cfg)
            }
        }
        "loadgen" => {
            let mut lcfg = LoadgenCfg {
                serve: serve_cfg_from(&a)?,
                shard: shard_cfg_from(&a)?,
                ..Default::default()
            };
            let fast = a.flag("fast");
            lcfg.clients = a
                .get_usize_min("clients", lcfg.clients, 1)
                .map_err(anyhow::Error::msg)?;
            lcfg.requests_per_client = a
                .get_usize_min("requests", if fast { 3 } else { 16 }, 1)
                .map_err(anyhow::Error::msg)?;
            if a.options.contains_key("deadline-ms") {
                lcfg.deadline_ms =
                    Some(a.get_u64_min("deadline-ms", 0, 1).map_err(anyhow::Error::msg)?);
            }
            if let Some(mix) = a.options.get("mix") {
                lcfg.mix = parse_mix(mix)?;
            }
            // `--connect ADDR` drives a remote `serve --listen` server;
            // `--listen` is accepted as an alias for symmetry.
            let remote = a.options.get("connect").or_else(|| a.options.get("listen"));
            let report = if let Some(addr) = remote {
                serve::loadgen::run_loadgen_tcp(&make_sim(&a)?, addr, &lcfg)?
            } else if lcfg.shard.workers > 1 {
                serve::loadgen::run_loadgen_sharded(&make_spec(&a)?, &lcfg)?
            } else {
                serve::loadgen::run_loadgen(&make_sim(&a)?, &lcfg)?
            };
            println!("{}", report.render());
            Ok(())
        }
        "" => bail!("missing command"),
        other => bail!("unknown command {:?}", other),
    }
}

/// `--stats-every S`: log a compact metrics-registry snapshot at info
/// level every `every_s` seconds until the process exits. Detached —
/// serving never waits on it, and reading the registry is lock-free so
/// the reporter cannot stall the hot path.
fn spawn_stats_reporter(every_s: u64) {
    std::thread::Builder::new()
        .name("stats-reporter".to_string())
        .spawn(move || loop {
            std::thread::sleep(Duration::from_secs(every_s));
            logging::log(1, &serve::metrics::snapshot().render_compact());
        })
        .expect("spawn stats reporter");
}

/// The serving knobs `serve` and `loadgen` share — all strictly parsed.
fn serve_cfg_from(a: &Args) -> Result<ServeCfg> {
    let defaults = ServeCfg::default();
    let window_ms = a
        .get_u64_min("batch-window", defaults.batch_window.as_millis() as u64, 1)
        .map_err(anyhow::Error::msg)?;
    let drain_ms = a
        .get_u64_min("drain-timeout", defaults.drain_timeout.as_millis() as u64, 1)
        .map_err(anyhow::Error::msg)?;
    // --idle-timeout and --max-conns default to off; present means a
    // strictly-parsed positive value (0/junk rejected, never ignored).
    let idle_timeout = if a.options.contains_key("idle-timeout") {
        let ms = a.get_u64_min("idle-timeout", 1, 1).map_err(anyhow::Error::msg)?;
        Some(Duration::from_millis(ms))
    } else {
        defaults.idle_timeout
    };
    let max_conns = if a.options.contains_key("max-conns") {
        Some(a.get_usize_min("max-conns", 1, 1).map_err(anyhow::Error::msg)?)
    } else {
        defaults.max_conns
    };
    Ok(ServeCfg {
        queue_cap: a
            .get_usize_min("queue-cap", defaults.queue_cap, 1)
            .map_err(anyhow::Error::msg)?,
        batch_window: Duration::from_millis(window_ms),
        max_batch: a
            .get_usize_min("max-batch", defaults.max_batch, 1)
            .map_err(anyhow::Error::msg)?,
        drain_timeout: Duration::from_millis(drain_ms),
        idle_timeout,
        max_conns,
    })
}

/// The shard-pool knobs `serve` and `loadgen` share.
fn shard_cfg_from(a: &Args) -> Result<ShardCfg> {
    let defaults = ShardCfg::default();
    Ok(ShardCfg {
        workers: a
            .get_usize_min("workers", defaults.workers, 1)
            .map_err(anyhow::Error::msg)?,
        replicate_hot: a.flag("replicate-hot"),
        hot_min: a
            .get_usize_min("hot-min", defaults.hot_min, 1)
            .map_err(anyhow::Error::msg)?,
    })
}

/// `--mix model:quant[,model:quant...]`.
fn parse_mix(raw: &str) -> Result<Vec<(String, String)>> {
    let mut mix = Vec::new();
    for part in raw.split(',') {
        let (model, quant) = part
            .split_once(':')
            .with_context(|| format!("--mix entry {:?} is not model:quant", part))?;
        anyhow::ensure!(
            !model.is_empty() && !quant.is_empty(),
            "--mix entry {:?} is not model:quant",
            part
        );
        mix.push((model.to_string(), quant.to_string()));
    }
    anyhow::ensure!(!mix.is_empty(), "--mix needs at least one model:quant entry");
    Ok(mix)
}
