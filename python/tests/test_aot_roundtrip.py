"""AOT lowering round-trip: artifacts must re-lower deterministically and
the HLO text must contain the structures the runtime relies on."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, registry as R

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def lower(adef):
    fn, specs, _, _ = aot.build_artifact(adef)
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def test_lowering_deterministic():
    adef = R.ArtifactDef("sim-opt-125m", "eval", "abfp_w4a4_n64")
    assert lower(adef) == lower(adef)


def test_eval_artifact_parameter_count_survives_lowering():
    """XLA must not prune params (the capture bug class): the HLO entry
    computation must declare exactly len(inputs) parameters."""
    for purpose, quant in [
        ("eval", "fp32"),
        ("eval", "mse_w4a4"),
        ("capture", "fp32"),
    ]:
        adef = R.ArtifactDef("sim-opt-125m", purpose, quant)
        fn, specs, inputs, _ = aot.build_artifact(adef)
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        # count parameters of the ENTRY computation only (nested fusion
        # computations declare their own "parameter(" instructions)
        entry = text[text.index("ENTRY"):]
        entry = entry[: entry.index("\n}")]
        nparams = entry.count("parameter(")
        assert nparams == len(inputs), (purpose, quant, nparams, len(inputs))


def test_eval_artifact_numerics_match_direct_execution():
    """The lowered artifact computes the same nll asdirect jax execution."""
    adef = R.ArtifactDef("sim-opt-125m", "eval", "fp32")
    fn, specs, inputs, _ = aot.build_artifact(adef)
    rs = np.random.RandomState(0)
    args = []
    for spec in specs:
        if spec.dtype == jnp.int32:
            args.append(jnp.asarray(rs.randint(0, 32, spec.shape).astype("int32")))
        else:
            args.append(jnp.asarray(rs.randn(*spec.shape).astype("float32") * 0.02))
    direct = fn(*args)[0]
    jitted = jax.jit(fn)(*args)[0]
    np.testing.assert_allclose(np.asarray(direct), np.asarray(jitted), rtol=1e-5)


def test_artifact_hash_sensitive_to_config():
    a = aot.artifact_hash(R.ArtifactDef("sim-opt-125m", "eval", "fp32"))
    b = aot.artifact_hash(R.ArtifactDef("sim-opt-125m", "eval", "abfp_w4a4_n64"))
    c = aot.artifact_hash(R.ArtifactDef("sim-opt-350m", "eval", "fp32"))
    assert len({a, b, c}) == 3


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_on_disk_hlo_declares_entry():
    path = os.path.join(ART, "sim-opt-125m", "eval_fp32.hlo.txt")
    text = open(path).read()
    assert "ENTRY" in text
    assert "parameter(0)" in text
