"""Quantizer combinators: spec dispatch, STE gradients (Eqn 5)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import formats as F
from compile import quantizers as Q
from compile.kernels import ref


def rand(shape, seed=0, scale=3.0):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32) * scale
    )


def test_none_is_identity():
    x = rand((4, 128))
    y = Q.apply(x, Q.NONE)
    assert y is x


def test_abfp_spec_dispatch():
    x = rand((4, 128))
    spec = Q.abfp(F.INT4, 64)
    np.testing.assert_array_equal(
        np.asarray(Q.apply(x, spec)), np.asarray(ref.abfp_qdq(x, F.INT4, 64))
    )


def test_abfp2_spec_dispatch():
    x = rand((4, 128))
    spec = Q.abfp2(F.INT4, 64)
    np.testing.assert_array_equal(
        np.asarray(Q.apply(x, spec)), np.asarray(ref.abfp2_qdq(x, F.INT4, 64))
    )


def test_abfp2_pallas_and_ref_paths_agree():
    x = rand((4, 128), seed=7)
    spec = Q.abfp2(F.INT8, 64)
    a = np.asarray(Q.apply(x, spec, use_pallas=True))
    b = np.asarray(Q.apply(x, spec, use_pallas=False))
    np.testing.assert_array_equal(a, b)


def test_ste_abfp2_gradient_is_identity():
    """abfp2's ceil-coded scale >= raw absmax, so the PWL mask stays
    all-ones exactly like plain ABFP."""
    x = rand((4, 128), seed=9)
    spec = Q.abfp2(F.INT4, 64)

    def f(v):
        return jnp.sum(Q.apply(v, spec, ste=True) * 2.0)

    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones((4, 128)), rtol=0)


def test_static_requires_alpha():
    with pytest.raises(AssertionError):
        Q.apply(rand((4, 128)), Q.static_int(4))


def test_static_int_dispatch():
    x = rand((4, 128))
    a = jnp.float32(2.0)
    got = Q.apply(x, Q.static_int(8), alpha=a)
    want = ref.static_int_qdq(x, a, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_and_ref_paths_agree():
    x = rand((4, 128), seed=5)
    for spec in (Q.abfp(F.E4M3, 64), Q.w_pcmax_int(4)):
        a = np.asarray(Q.apply(x, spec, use_pallas=True))
        b = np.asarray(Q.apply(x, spec, use_pallas=False))
        np.testing.assert_array_equal(a, b)


# --- PWL straight-through estimator (Eqn 5) --------------------------------


def test_ste_forward_unchanged():
    x = rand((4, 128))
    spec = Q.abfp(F.INT4, 64)
    np.testing.assert_array_equal(
        np.asarray(Q.apply(x, spec, ste=True)),
        np.asarray(Q.apply(x, spec, ste=False)),
    )


def test_ste_abfp_gradient_is_identity():
    """ABFP never clips (scale = absmax), so the PWL mask is all-ones."""
    x = rand((4, 128), seed=1)
    spec = Q.abfp(F.INT4, 64)

    def f(v):
        return jnp.sum(Q.apply(v, spec, ste=True) * 3.0)

    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones((4, 128)), rtol=0)


def test_ste_static_gradient_masks_clipped():
    """Static quant with alpha=1: |x|>1 gets zero gradient, |x|<=1 passes."""
    x = jnp.asarray([[0.5, -0.5, 2.0, -2.0]], jnp.float32)
    spec = Q.static_int(4)

    def f(v):
        return jnp.sum(Q.apply(v, spec, alpha=jnp.float32(1.0), ste=True))

    g = np.asarray(jax.grad(f)(x))
    np.testing.assert_array_equal(g, [[1.0, 1.0, 0.0, 0.0]])


def test_ste_grad_through_loss():
    """End-to-end: gradient flows through a quantized linear layer."""
    x = rand((8, 128), seed=2)
    w = rand((16, 128), seed=3, scale=0.1)
    spec = Q.abfp(F.INT4, 64)

    def loss(w_):
        y = Q.apply(x, spec, ste=True) @ Q.apply(w_, spec, ste=True).T
        return jnp.mean(y * y)

    g = np.asarray(jax.grad(loss)(w))
    assert np.isfinite(g).all()
    assert np.abs(g).max() > 0
