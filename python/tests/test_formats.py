"""Format-descriptor invariants: grids, fmax, parsing."""

import numpy as np
import pytest

from compile import formats as F


def test_named_formats():
    assert F.INT4.qmax == 7
    assert F.INT8.qmax == 127
    assert F.E2M1.fmax == 6.0
    assert F.E1M2.fmax == 3.5
    assert F.E4M3.fmax == 448.0  # NaN-reserved OCP convention


def test_parse_roundtrip():
    for name in ("int4", "int8", "e2m1", "e1m2", "e4m3"):
        assert F.parse(name).name == name
    assert F.parse("int6").qmax == 31
    # no-inf convention: top binade is all values (57344 would be the
    # IEEE-style fmax with the top exponent reserved for inf/nan)
    assert F.parse("e5m2").fmax == 114688.0
    with pytest.raises(ValueError):
        F.parse("bogus")


def test_e2m1_grid():
    assert F.E2M1.grid() == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def test_e1m2_grid_near_uniform():
    # E1M2's grid is the reason the paper finds E1M2 ≈ INT4 (Table II).
    g = F.E1M2.grid()
    assert g == [0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5]
    steps = np.diff(g)
    assert np.allclose(steps, 0.5)


def test_grid_sizes():
    # 1 sign bit: total non-negative code points = 2^(e+m); minus NaN if reserved.
    for fmt in (F.E2M1, F.E1M2):
        assert len(fmt.grid()) == 2 ** (fmt.e + fmt.m)
    assert len(F.E4M3.grid()) == 2 ** 7 - 1


def test_grid_contains_fmax_and_subnormals():
    for fmt in (F.E2M1, F.E1M2, F.E4M3):
        g = fmt.grid()
        assert g[-1] == fmt.fmax
        assert fmt.smallest_subnormal in g
        assert g[0] == 0.0


def test_e4m3_nan_reservation():
    g448 = F.E4M3.grid()
    g480 = F.FpFormat(4, 3).grid()
    assert 480.0 in g480 and 480.0 not in g448
    assert len(g480) == len(g448) + 1
