"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, formats and vector lengths; every comparison is
exact equality (the kernel and the oracle must implement the *same*
rounding, not merely be close).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import abfp, fpquant, intquant, ref

FORMATS = [F.INT4, F.INT8, F.E2M1, F.E1M2, F.E4M3]


def rand(shape, seed, scale=4.0, heavy_tail=False):
    rs = np.random.RandomState(seed)
    x = rs.randn(*shape).astype(np.float32) * scale
    if heavy_tail:
        x *= np.exp(rs.randn(*shape)).astype(np.float32)
    return jnp.asarray(x)


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f.name)
@pytest.mark.parametrize("n", [64, 128])
def test_abfp_kernel_matches_ref(fmt, n):
    x = rand((16, 256), seed=0, heavy_tail=True)
    a = np.asarray(ref.abfp_qdq(x, fmt, n))
    b = np.asarray(abfp.abfp_qdq(x, fmt, n))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([1, 3, 8, 17]),
    kmul=st.sampled_from([1, 2, 3, 5]),
    n=st.sampled_from([64, 128]),
    fmt=st.sampled_from(FORMATS),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_abfp_kernel_hypothesis(rows, kmul, n, fmt, seed, scale):
    x = rand((rows, kmul * n), seed=seed, scale=scale)
    a = np.asarray(ref.abfp_qdq(x, fmt, n))
    b = np.asarray(abfp.abfp_qdq(x, fmt, n))
    np.testing.assert_array_equal(a, b)


def test_abfp_3d_input():
    x = rand((4, 7, 128), seed=3)
    a = np.asarray(ref.abfp_qdq(x, F.INT4, 64))
    b = np.asarray(abfp.abfp_qdq(x, F.INT4, 64))
    np.testing.assert_array_equal(a, b)


def test_abfp_rejects_bad_n():
    with pytest.raises(AssertionError):
        abfp.abfp_qdq(rand((4, 100), 0), F.INT4, 64)


@settings(max_examples=20, deadline=None)
@given(
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
    per_channel=st.booleans(),
)
def test_static_int_kernel_hypothesis(bits, seed, per_channel):
    x = rand((32, 192), seed=seed, heavy_tail=True)
    if per_channel:
        alpha = jnp.max(jnp.abs(x), axis=0)
    else:
        alpha = jnp.float32(2.5)
    a = np.asarray(ref.static_int_qdq(x, alpha, bits))
    b = np.asarray(intquant.static_int_qdq(x, alpha, bits))
    np.testing.assert_array_equal(a, b)


def test_pcmax_weight_kernel():
    w = rand((48, 256), seed=9, heavy_tail=True)
    a = np.asarray(ref.per_channel_max_weight_qdq(w, 4))
    b = np.asarray(intquant.per_channel_max_weight_qdq(w, 4))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("fmt", [F.E2M1, F.E1M2, F.E4M3], ids=lambda f: f.name)
def test_fp_round_kernel_matches_ref(fmt):
    x = rand((8, 128), seed=1, heavy_tail=True)
    a = np.asarray(ref.fp_round(x, fmt))
    b = np.asarray(fpquant.fp_round(x, fmt))
    np.testing.assert_array_equal(a, b)


# --- two-level (abfp2) kernel vs oracle ------------------------------------


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f.name)
@pytest.mark.parametrize("n", [64, 128])
def test_abfp2_kernel_matches_ref(fmt, n):
    x = rand((16, 256), seed=0, heavy_tail=True)
    a = np.asarray(ref.abfp2_qdq(x, fmt, n))
    b = np.asarray(abfp.abfp2_qdq(x, fmt, n))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([1, 3, 8, 17]),
    kmul=st.sampled_from([1, 2, 3, 5]),
    n=st.sampled_from([64, 128]),
    fmt=st.sampled_from(FORMATS),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_abfp2_kernel_hypothesis(rows, kmul, n, fmt, seed, scale):
    x = rand((rows, kmul * n), seed=seed, scale=scale)
    a = np.asarray(ref.abfp2_qdq(x, fmt, n))
    b = np.asarray(abfp.abfp2_qdq(x, fmt, n))
    np.testing.assert_array_equal(a, b)


def test_abfp2_3d_input():
    x = rand((4, 7, 128), seed=3)
    a = np.asarray(ref.abfp2_qdq(x, F.INT4, 64))
    b = np.asarray(abfp.abfp2_qdq(x, F.INT4, 64))
    np.testing.assert_array_equal(a, b)


def test_abfp2_scale_codes_never_undershoot():
    """Ceil-coded scales reconstruct >= the raw per-vector absmax, so the
    payload never hard-clips (the property ABFP is built on)."""
    x = rand((32, 256), seed=21, heavy_tail=True)
    alpha_hat, gamma = ref.abfp2_scales(x, 64)
    xb = np.asarray(x).reshape(32, 4, 64)
    raw = np.abs(xb).max(axis=-1)
    ah = np.asarray(alpha_hat)
    nz = raw > 0
    # BF16 rounding of gamma can shave ~2^-9 relative; ceil wins it back
    # except exactly at the row max, where alpha_hat == bf16(gamma).
    assert (ah[nz] >= raw[nz] * (1 - 2.0**-8)).all()
    assert np.asarray(gamma).shape == (32, 1)


def test_abfp2_zero_vector_is_zero():
    x = jnp.zeros((4, 128), jnp.float32)
    for fmt in FORMATS:
        y = np.asarray(ref.abfp2_qdq(x, fmt, 64))
        np.testing.assert_array_equal(y, np.zeros((4, 128), np.float32))


def test_abfp2_error_close_to_abfp():
    """Two-level scale coding costs at most a small extra quantization
    error vs plain ABFP (that is the point of 8-bit scale codes)."""
    x = rand((64, 512), seed=5, heavy_tail=True)
    for fmt in (F.INT4, F.INT8):
        e1 = float(jnp.mean((ref.abfp_qdq(x, fmt, 64) - x) ** 2))
        e2 = float(jnp.mean((ref.abfp2_qdq(x, fmt, 64) - x) ** 2))
        assert e2 <= 2.5 * e1 + 1e-12, (fmt.name, e1, e2)


def test_abfp2_scale_bits_sweep():
    """More scale bits -> scales closer to raw absmax -> error approaches
    plain-ABFP error monotonically (within noise)."""
    x = rand((16, 256), seed=8, heavy_tail=True)
    errs = []
    for sb in (2, 4, 8, 12):
        y = ref.abfp2_qdq(x, F.INT4, 64, scale_bits=sb)
        errs.append(float(jnp.mean((y - x) ** 2)))
    assert errs[0] >= errs[1] >= errs[2] * 0.999
    e_abfp = float(jnp.mean((ref.abfp_qdq(x, F.INT4, 64) - x) ** 2))
    assert abs(errs[3] - e_abfp) / e_abfp < 0.05


# --- oracle semantics ------------------------------------------------------


@pytest.mark.parametrize("fmt", [F.E2M1, F.E1M2, F.E4M3], ids=lambda f: f.name)
def test_fp_round_lands_on_grid(fmt):
    x = rand((4, 128), seed=2, heavy_tail=True)
    y = np.asarray(ref.fp_round(x, fmt)).flatten()
    grid = np.array(fmt.grid(), np.float32)
    full = np.concatenate([-grid[::-1], grid])
    for v in y:
        assert np.isclose(full, v, rtol=0, atol=0).any(), v


@pytest.mark.parametrize("fmt", [F.E2M1, F.E1M2, F.E4M3], ids=lambda f: f.name)
def test_fp_round_is_nearest(fmt):
    """Grid rounding must pick (one of) the nearest grid values."""
    rs = np.random.RandomState(7)
    x = (rs.randn(512) * fmt.fmax / 2).astype(np.float32)
    y = np.asarray(ref.fp_round(jnp.asarray(x), fmt))
    grid = np.array(fmt.grid(), np.float64)
    full = np.concatenate([-grid[::-1], grid])
    for xi, yi in zip(x, y):
        best = np.min(np.abs(full - np.float64(xi)))
        if abs(xi) <= fmt.fmax:
            assert abs(yi - np.float64(xi)) <= best + 1e-12, (xi, yi)


def test_fp_round_fixed_points():
    """Every grid value is a fixed point of the rounding."""
    for fmt in (F.E2M1, F.E1M2, F.E4M3):
        g = np.array(fmt.grid(), np.float32)
        y = np.asarray(ref.fp_round(jnp.asarray(g[None, :]), fmt))[0]
        np.testing.assert_array_equal(g, y)


def test_fp_round_saturates():
    y = np.asarray(ref.fp_round(jnp.asarray([[1e30, -1e30]]), F.E4M3))
    np.testing.assert_array_equal(y, [[448.0, -448.0]])


def test_fp_round_rne_tie():
    # 2.5 is exactly between E2M1 grid points 2 and 3 -> ties to even
    # mantissa (2.0 has mantissa bit 0, 3.0 has mantissa bit 1).
    y = np.asarray(ref.fp_round(jnp.asarray([[2.5, -2.5, 5.0]]), F.E2M1))
    np.testing.assert_array_equal(y, [[2.0, -2.0, 4.0]])


def test_int_qdq_clips():
    x = jnp.asarray([[100.0, -100.0, 0.4, -0.4]])
    y = np.asarray(ref.int_qdq(x, jnp.float32(1.0), 4))
    np.testing.assert_array_equal(y, [[7.0, -7.0, 0.0, -0.0]])


def test_abfp_never_clips():
    """ABFP scales by the absmax, so the largest element survives QDQ
    with at most grid-rounding error (never hard clipping)."""
    x = rand((8, 128), seed=11, heavy_tail=True)
    for fmt in FORMATS:
        y = np.asarray(ref.abfp_qdq(x, fmt, 64))
        xm = np.asarray(x)
        # absmax positions: relative error bounded by half a grid step
        idx = np.argmax(np.abs(xm), axis=1)
        for r, c in enumerate(idx):
            rel = abs(y[r, c] - xm[r, c]) / abs(xm[r, c])
            assert rel < 0.01, (fmt.name, rel)


def test_abfp_qdq_idempotent():
    x = rand((8, 128), seed=13)
    for fmt in FORMATS:
        y1 = ref.abfp_qdq(x, fmt, 64)
        y2 = ref.abfp_qdq(y1, fmt, 64)
        # Not exactly idempotent in general (scale re-rounding), but y2
        # must stay within one grid step of y1.
        err = np.max(np.abs(np.asarray(y1) - np.asarray(y2)))
        scale = float(np.max(np.abs(np.asarray(y1)))) + 1e-9
        assert err / scale < 0.2, fmt.name


def test_abfp_zero_vector_is_zero():
    x = jnp.zeros((4, 128), jnp.float32)
    for fmt in FORMATS:
        y = np.asarray(ref.abfp_qdq(x, fmt, 64))
        np.testing.assert_array_equal(y, np.zeros((4, 128), np.float32))
