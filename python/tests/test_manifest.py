"""Manifest/aot schema tests: what the Rust coordinator depends on."""

import json
import os

import pytest

from compile import registry as R
from compile import aot
from compile.models import common as C

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_artifact_defs_cover_experiments():
    ids = {d.id for d in R.artifact_defs()}
    # every experiment's artifact must exist in the matrix
    for m in ("sim-opt-125m", "sim-opt-350m", "sim-opt-1.3b", "sim-opt-2.7b"):
        for q in R.OPT_EVAL_CONFIGS:
            assert f"{m}/eval_{q}" in ids
        assert f"{m}/capture_fp32" in ids
        for q in R.OPT_TRAIN_CONFIGS:
            assert f"{m}/train_{q}" in ids
    for m in ("sim-codegen-2b", "sim-codegen-6b"):
        assert f"{m}/eval_logits_abfp_w4a4_n64" in ids
    for m in ("sim-bert-base", "sim-bert-large", "sim-vit-16", "sim-vit-32"):
        assert f"{m}/eval_abfp_w4a8_n64" in ids


def test_widths_tile_abfp_vector_lengths():
    for cfg in R.MODELS.values():
        assert cfg.d % 128 == 0, cfg.name
        assert cfg.d_ff % 128 == 0, cfg.name


def test_build_artifact_io_specs():
    adef = R.ArtifactDef("sim-opt-125m", "eval", "mse_w4a4")
    _, arg_specs, inputs, outputs = aot.build_artifact(adef)
    assert len(arg_specs) == len(inputs)
    kinds = [i["kind"] for i in inputs]
    cfg = R.MODELS["sim-opt-125m"]
    nsites = 4 * cfg.L
    assert kinds.count("ascale") == nsites
    assert kinds.count("data") == 1
    assert outputs == [{"name": "nll_sum", "shape": [], "dtype": "f32"}]


def test_build_artifact_train_io():
    adef = R.ArtifactDef("sim-opt-125m", "train", "qat_w4a4_n64")
    _, _, inputs, outputs = aot.build_artifact(adef)
    nparams = len(R.MODELS["sim-opt-125m"].__class__ and
                  aot.param_specs_for(R.MODELS["sim-opt-125m"]))
    kinds = [i["kind"] for i in inputs]
    assert kinds.count("param") == nparams
    assert kinds.count("adam_m") == nparams
    assert kinds.count("adam_v") == nparams
    assert kinds.count("scalar") == 2
    assert len(outputs) == 3 * nparams + 1
    assert outputs[-1]["name"] == "loss"


def test_smooth_inputs_present_for_abfp():
    adef = R.ArtifactDef("sim-opt-125m", "eval", "abfp_w4a4_n64")
    _, _, inputs, _ = aot.build_artifact(adef)
    cfg = R.MODELS["sim-opt-125m"]
    smooth = [i for i in inputs if i["kind"] == "smooth"]
    assert len(smooth) == 4 * cfg.L
    dims = C.site_dims(cfg)
    for s in smooth:
        site = s["name"].split(".", 1)[1]
        assert s["shape"] == [dims[site]]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_on_disk_consistent():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert set(man["models"]) == set(R.MODELS)
    for aid, a in man["artifacts"].items():
        assert os.path.exists(os.path.join(ART, a["file"])), aid
        assert a["model"] in man["models"]
        # input ordering contract: params first, then quant, then state/data
        kinds = [i["kind"] for i in a["inputs"]]
        order = {"param": 0, "smooth": 1, "ascale": 1,
                 "adam_m": 2, "adam_v": 3, "scalar": 4, "data": 5}
        ranks = [order[k] for k in kinds]
        assert ranks == sorted(ranks), aid


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "goldens", "quant_goldens.json")),
    reason="goldens not built",
)
def test_goldens_schema():
    with open(os.path.join(ART, "goldens", "quant_goldens.json")) as f:
        g = json.load(f)
    assert len(g["probe"]) == 8 * 128
    for key in ("grid_e2m1", "abfp_int4_n64", "static_int8_a2.5",
                "pcmax_w_int4", "fp_round_e4m3"):
        assert key in g
