"""Model-family shape/semantics tests (L2)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import formats as F
from compile import quantizers as Q
from compile import registry as R
from compile import train as T
from compile.models import bert, common as C, opt, vit


def init_params(cfg, seed=0):
    mod = {"opt": opt, "bert": bert, "vit": vit}[cfg.arch]
    rs = np.random.RandomState(seed)
    p = {}
    for name, shape, kind in mod.param_specs(cfg):
        if kind == "zeros":
            v = np.zeros(shape, np.float32)
        elif kind == "ones":
            v = np.ones(shape, np.float32)
        elif kind in ("lognormal", "lngain"):
            v = np.exp(rs.randn(*shape) * 0.5).astype(np.float32)
        elif kind == "residual":
            v = (rs.randn(*shape) * 0.02 / np.sqrt(2 * cfg.L)).astype(np.float32)
        else:
            v = (rs.randn(*shape) * 0.02).astype(np.float32)
        p[name] = jnp.asarray(v)
    return p


CFG = R.MODELS["sim-opt-125m"]


def test_opt_forward_shapes():
    p = init_params(CFG)
    toks = jnp.zeros((2, CFG.seq), jnp.int32)
    logits = opt.forward(p, toks, CFG, C.FP32, {})
    assert logits.shape == (2, CFG.seq, CFG.vocab)


def test_opt_causality():
    """Changing a future token must not affect earlier logits."""
    p = init_params(CFG)
    rs = np.random.RandomState(0)
    t1 = rs.randint(0, CFG.vocab, (1, CFG.seq)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % CFG.vocab
    l1 = np.asarray(opt.forward(p, jnp.asarray(t1), CFG, C.FP32, {}))
    l2 = np.asarray(opt.forward(p, jnp.asarray(t2), CFG, C.FP32, {}))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)
    assert np.abs(l1[0, -1] - l2[0, -1]).max() > 0


def test_opt_nll_matches_uniform_at_init_scale():
    """With tiny random weights, NLL/token ≈ ln(vocab)."""
    p = init_params(CFG)
    rs = np.random.RandomState(1)
    toks = jnp.asarray(rs.randint(0, CFG.vocab, (4, CFG.seq)).astype(np.int32))
    nll = float(opt.nll_sum(opt.forward(p, toks, CFG, C.FP32, {}), toks))
    per_tok = nll / (4 * (CFG.seq - 1))
    assert abs(per_tok - np.log(CFG.vocab)) < 0.5


def test_opt_quantized_forward_close_to_fp32():
    p = init_params(CFG)
    rs = np.random.RandomState(2)
    toks = jnp.asarray(rs.randint(0, CFG.vocab, (2, CFG.seq)).astype(np.int32))
    w = C.QuantWiring(Q.abfp(F.INT8, 64), Q.abfp(F.INT8, 64))
    lf = np.asarray(opt.forward(p, toks, CFG, C.FP32, {}))
    lq = np.asarray(opt.forward(p, toks, CFG, w, {}))
    rel = np.abs(lf - lq).max() / (np.abs(lf).max() + 1e-9)
    assert 0 < rel < 0.2


def test_smoothing_identity_when_ones():
    p = init_params(CFG)
    rs = np.random.RandomState(3)
    toks = jnp.asarray(rs.randint(0, CFG.vocab, (2, CFG.seq)).astype(np.int32))
    wiring = C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), smooth=True)
    dims = C.site_dims(CFG)
    sites = {
        s: C.SiteInputs(smooth=jnp.ones((dims[s],), jnp.float32))
        for s in C.all_site_names(CFG)
    }
    l1 = np.asarray(opt.forward(p, toks, CFG, wiring, sites))
    l2 = np.asarray(opt.forward(p, toks, CFG, wiring, {}))
    np.testing.assert_array_equal(l1, l2)


def test_output_quant_changes_logits():
    """f_q^y (Eqn 9) must actually apply: an output-quantized wiring gives
    different logits from the same wiring without oq."""
    p = init_params(CFG)
    rs = np.random.RandomState(4)
    toks = jnp.asarray(rs.randint(0, CFG.vocab, (2, CFG.seq)).astype(np.int32))
    base = C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64))
    oq = C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64)
    )
    lb = np.asarray(opt.forward(p, toks, CFG, base, {}))
    lo = np.asarray(opt.forward(p, toks, CFG, oq, {}))
    assert np.abs(lb - lo).max() > 0
    # int8 output QDQ is mild: logits stay close
    rel = np.abs(lb - lo).max() / (np.abs(lb).max() + 1e-9)
    assert rel < 0.2


def test_layer_override_resolution():
    w8 = C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64))
    mixed = C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), smooth=True, ste=True,
        layer_overrides=((0, w8), (-1, w8)),
    )
    L = 3
    assert mixed.for_layer(0, L).aq.fmt.bits == 8
    assert mixed.for_layer(L - 1, L).aq.fmt.bits == 8
    assert mixed.for_layer(1, L).aq.fmt.bits == 4
    # overrides inherit the parent's model-global flags
    assert mixed.for_layer(0, L).smooth and mixed.for_layer(0, L).ste
    # no overrides -> identity
    base = C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64))
    assert base.for_layer(1, L) is base


def test_mixed_precision_between_uniform_bounds():
    """Boundary-8-bit mixed wiring must land between all-4-bit and
    all-8-bit activations in logit error vs FP32."""
    p = init_params(CFG)
    rs = np.random.RandomState(5)
    toks = jnp.asarray(rs.randint(0, CFG.vocab, (2, CFG.seq)).astype(np.int32))
    lf = np.asarray(opt.forward(p, toks, CFG, C.FP32, {}))

    def err(wiring):
        lq = np.asarray(opt.forward(p, toks, CFG, wiring, {}))
        return float(np.abs(lf - lq).mean())

    w4 = C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64))
    w8 = C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64))
    mixed = C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64),
        layer_overrides=((0, w8), (-1, w8)),
    )
    e4, e8, em = err(w4), err(w8), err(mixed)
    # CFG has L=2 so every block is a boundary block: mixed == all-8-bit
    assert e8 <= em <= e4
    np.testing.assert_allclose(em, e8, rtol=1e-6)


def test_capture_sites_order_and_shapes():
    p = init_params(CFG)
    toks = jnp.zeros((2, CFG.seq), jnp.int32)
    acts = opt.capture_acts(p, toks, CFG)
    names = C.all_site_names(CFG)
    dims = C.site_dims(CFG)
    # 4L sites + the _anchor scalar that pins tail params in the graph
    assert len(acts) == len(names) + 1 == 4 * CFG.L + 1
    for name, a in zip(names, acts[:-1]):
        assert a.shape == (2 * CFG.seq, dims[name])
    assert acts[-1].shape == ()


def test_bert_shapes():
    cfg = R.MODELS["sim-bert-base"]
    p = init_params(cfg)
    toks = jnp.zeros((2, cfg.seq), jnp.int32)
    sl, el = bert.forward(p, toks, cfg, C.FP32, {})
    assert sl.shape == (2, cfg.seq) and el.shape == (2, cfg.seq)


def test_bert_not_causal():
    cfg = R.MODELS["sim-bert-base"]
    p = init_params(cfg)
    rs = np.random.RandomState(0)
    t1 = rs.randint(0, cfg.vocab, (1, cfg.seq)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % cfg.vocab
    s1, _ = bert.forward(p, jnp.asarray(t1), cfg, C.FP32, {})
    s2, _ = bert.forward(p, jnp.asarray(t2), cfg, C.FP32, {})
    assert np.abs(np.asarray(s1)[0, 0] - np.asarray(s2)[0, 0]) > 0


def test_vit_shapes_and_patchify():
    cfg = R.MODELS["sim-vit-16"]
    p = init_params(cfg)
    imgs = jnp.asarray(np.random.RandomState(0).rand(2, 32, 32, 3).astype(np.float32))
    logits = vit.forward(p, imgs, cfg, C.FP32, {})
    assert logits.shape == (2, cfg.classes)
    patches = vit.patchify(imgs, 4)
    assert patches.shape == (2, 64, 48)
    # patch content: first patch equals the top-left 4x4 block
    np.testing.assert_array_equal(
        np.asarray(patches)[0, 0], np.asarray(imgs)[0, :4, :4, :].flatten()
    )


def test_train_step_reduces_loss():
    """A few Adam steps on one batch must reduce the LM loss."""
    cfg = R.MODELS["sim-opt-125m"]
    p = init_params(cfg)
    names = list(p.keys())
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in p.items()}

    def loss_fn(pp, toks):
        logits = opt.forward(pp, toks, cfg, C.FP32, {})
        return opt.nll_sum(logits, toks) / float(toks.shape[0] * (cfg.seq - 1))

    step = jax.jit(T.make_train_step(loss_fn, names))
    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(0, 16, (4, cfg.seq)).astype(np.int32))
    plist = [p[k] for k in names]
    mlist = [m[k] for k in names]
    vlist = [v[k] for k in names]
    losses = []
    for it in range(5):
        out = step(plist, mlist, vlist, jnp.float32(it + 1), jnp.float32(1e-3), toks)
        P = len(names)
        plist, mlist, vlist = out[:P], out[P:2 * P], out[2 * P:3 * P]
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0]
