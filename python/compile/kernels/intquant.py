"""Pallas kernels for static-scale integer fake-quantization (Eqns 1-3).

Two variants:

* per-tensor — one calibrated clip range ``alpha`` for the whole tensor
  (the paper's static MSE-calibrated activations);
* per-channel — one ``alpha`` per channel of the last axis (the paper's
  per-channel max weight calibration, and RPTQ's cluster-wise activation
  scales, which are expressed as a per-channel scale vector).

The tile layout mirrors the ABFP kernel: the last axis is the lane axis;
per-channel scales ride along as a second (row-broadcast) operand so the
QDQ stays a single VMEM-resident elementwise pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int_qdq_kernel(x_ref, a_ref, o_ref, *, qmax):
    x = x_ref[...]
    alpha = a_ref[...]
    alpha = jnp.where(alpha > 0, alpha, 1.0)
    s = qmax / alpha
    q = jnp.clip(jnp.round(x * s), -qmax, qmax)
    o_ref[...] = (q / s).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits",))
def static_int_qdq_2d(x, alpha, bits: int):
    """Static integer QDQ of ``(R, K)`` x.

    ``alpha`` is ``(1, 1)`` (per-tensor) or ``(1, K)`` (per-channel on the
    last axis); it is broadcast over rows inside the kernel tile.
    """
    R, K = x.shape
    qmax = float(2 ** (bits - 1) - 1)
    ar, ak = alpha.shape
    return pl.pallas_call(
        functools.partial(_int_qdq_kernel, qmax=qmax),
        out_shape=jax.ShapeDtypeStruct((R, K), jnp.float32),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((R, K), lambda i: (0, 0)),
            pl.BlockSpec((ar, ak), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((R, K), lambda i: (0, 0)),
        interpret=True,
    )(x, alpha)


def static_int_qdq(x, alpha, bits: int):
    """Static integer QDQ along the last axis of an arbitrary-rank array.

    alpha: scalar array () / (1,) for per-tensor, or (K,) per-channel.
    """
    shape = x.shape
    x2 = x.reshape((-1, shape[-1]))
    a = jnp.asarray(alpha, jnp.float32)
    if a.ndim == 0:
        a2 = a.reshape((1, 1))
    elif a.shape == (1,):
        a2 = a.reshape((1, 1))
    else:
        assert a.shape == (shape[-1],), (a.shape, shape)
        a2 = a.reshape((1, shape[-1]))
    return static_int_qdq_2d(x2, a2, bits).reshape(shape)


def per_channel_max_weight_qdq(w, bits: int):
    """Per-output-channel max weight QDQ: alpha = absmax over input dim.

    w: (dout, din).  The absmax is computed in-graph (it depends only on
    the weights, so "static vs dynamic" is immaterial) and fed to the
    per-channel kernel with the channel axis transposed to the lane axis.
    """
    alpha = jnp.max(jnp.abs(w), axis=-1)  # (dout,)
    wt = w.T  # (din, dout): channel (dout) on the last axis
    return static_int_qdq(wt, alpha, bits).T
