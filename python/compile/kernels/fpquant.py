"""Pallas kernel for miniature-float (EeMm) grid rounding.

Standalone building block: rounds f32 values to the nearest representable
EeMm value (RNE, saturating, subnormals, no inf — see formats.py).  The
ABFP kernel fuses this same math with its per-vector scaling; this kernel
exists for (a) unscaled float QDQ experiments (e.g. raw-E4M3 output
quantization, §III "photonics hardware can involve output quantization"),
and (b) golden-table generation for the Rust mirror.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats as F


def _fp_round_kernel(x_ref, o_ref, *, m, emin, fmax):
    x = x_ref[...]
    ax = jnp.abs(x)
    safe = jnp.where(ax > 0, ax, 1.0)
    E = jnp.maximum(jnp.floor(jnp.log2(safe)), float(emin))
    ulp = jnp.exp2(E - m)
    q = jnp.minimum(jnp.round(ax / ulp) * ulp, fmax)
    o_ref[...] = (jnp.sign(x) * q).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("fmt",))
def fp_round_2d(x, fmt: F.FpFormat):
    R, K = x.shape
    return pl.pallas_call(
        functools.partial(
            _fp_round_kernel, m=fmt.m, emin=fmt.emin, fmax=fmt.fmax
        ),
        out_shape=jax.ShapeDtypeStruct((R, K), jnp.float32),
        grid=(1,),
        in_specs=[pl.BlockSpec((R, K), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((R, K), lambda i: (0, 0)),
        interpret=True,
    )(x)


def fp_round(x, fmt: F.FpFormat):
    """EeMm grid rounding of an arbitrary-rank array."""
    shape = x.shape
    x2 = x.reshape((-1, shape[-1])) if x.ndim != 2 else x
    out = fp_round_2d(x2, fmt)
    return out.reshape(shape)
