"""Pure-jnp reference oracles for the Pallas quantization kernels.

These are the *semantic definition* of every quantizer in the simulator
(Eqns 1-4 of the paper).  The Pallas kernels in this package must match
these bit-for-bit (pytest + hypothesis enforce it), and the Rust mirrors
in ``rust/src/formats/`` are validated against golden tables generated
from these functions.
"""

import jax.numpy as jnp

from .. import formats as F


def round_half_even(x):
    """Round to nearest integer, ties to even (IEEE RNE). jnp.round is RNE."""
    return jnp.round(x)


def int_qdq(x, scale, bits: int):
    """Symmetric integer fake-quant, Eqns (1)-(3).

    ``scale`` maps real values to integer steps (s = qmax / alpha) and is
    broadcast against ``x`` (scalar for per-tensor, vector for
    per-channel).  Returns DQ(Q(x)) in f32.
    """
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(round_half_even(x * scale), -qmax, qmax)
    return (q / scale).astype(jnp.float32)


def fp_round(x, fmt: F.FpFormat):
    """Round-to-nearest-even onto the EeMm grid, saturating at fmax.

    Subnormals of the target format are representable; there is no inf
    encoding (values beyond fmax clamp to fmax) — the convention of the
    FP8 paper [13] that INT-FP-QSim adopts.  For NaN-reserved formats
    (E4M3) fmax already excludes the NaN code point (448).
    """
    ax = jnp.abs(x)
    # Exponent of the containing binade, clamped to the subnormal floor.
    # Where ax == 0 the log2 is -inf; any finite placeholder works because
    # round(0/ulp)*ulp == 0 for every ulp.
    safe = jnp.where(ax > 0, ax, 1.0)
    E = jnp.floor(jnp.log2(safe))
    E = jnp.maximum(E, float(fmt.emin))
    ulp = jnp.exp2(E - fmt.m)
    q = round_half_even(ax / ulp) * ulp
    q = jnp.minimum(q, fmt.fmax)
    return (jnp.sign(x) * q).astype(jnp.float32)


def fp_qdq(x, scale, fmt: F.FpFormat):
    """Scaled float fake-quant: map alpha -> fmax, round on the grid, undo.

    ``scale`` is ``fmax / alpha`` (same convention as int_qdq: multiply
    into the grid, divide out).
    """
    return (fp_round(x * scale, fmt) / scale).astype(jnp.float32)


def _bf16(x):
    """Scale rounding: ABFP keeps per-vector scales in BF16 (paper §II-B-2)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def abfp_scales(x, n: int):
    """Per-vector absmax scales over length-``n`` chunks of the last axis.

    x: (..., K) with K % n == 0.  Returns (..., K//n) BF16-rounded scales
    (alpha, i.e. the absmax itself), with zeros replaced by 1 so empty
    vectors dequantize to zero instead of NaN.
    """
    K = x.shape[-1]
    assert K % n == 0, f"ABFP needs K % n == 0, got K={K} n={n}"
    xb = x.reshape(x.shape[:-1] + (K // n, n))
    alpha = jnp.max(jnp.abs(xb), axis=-1)
    alpha = _bf16(alpha)
    return jnp.where(alpha > 0, alpha, 1.0)


def abfp_qdq(x, fmt, n: int):
    """Adaptive Block Floating Point fake-quant (Eqn 4) along the last axis.

    Every length-``n`` vector is scaled by its own absmax (BF16), its
    payload quantized to ``fmt`` (integer or miniature float), and
    de-quantized.  Because the scale is the absmax, ABFP never clips.
    """
    K = x.shape[-1]
    alpha = abfp_scales(x, n)  # (..., K//n)
    xb = x.reshape(x.shape[:-1] + (K // n, n))
    a = alpha[..., None]
    if isinstance(fmt, F.IntFormat):
        s = float(fmt.qmax) / a
        y = int_qdq(xb, s, fmt.bits)
    else:
        s = float(fmt.fmax) / a
        y = fp_qdq(xb, s, fmt)
    return y.reshape(x.shape).astype(jnp.float32)


def abfp2_scales(x, n: int, scale_bits: int = 8):
    """Two-level ABFP scales (VS-Quant [5]; paper §II-B-2 "second-level
    quantization for the scale factors").

    Level 1: per-vector absmax alpha over length-``n`` chunks, as in ABFP.
    Level 2: per-row second-level scale gamma = max_j alpha_j (BF16), with
    each alpha re-expressed as an *unsigned ``scale_bits``-bit code* against
    gamma.  Codes round **up** (ceil) so the reconstructed scale never
    undershoots the vector's absmax — preserving ABFP's never-clips
    property at the cost of ≤1 code of extra step size.  The reconstructed
    scale is BF16, like every ABFP scale (§II-B-2) — which also keeps the
    eager oracle and the jitted kernel bit-identical (full-mantissa scales
    are vulnerable to XLA div/mul reassociation).

    Returns (alpha_hat, gamma) with shapes (..., K//n) and (..., 1).
    Storage: scale_bits/n + 16/K bits per element (vs 16/n for ABFP).
    """
    K = x.shape[-1]
    assert K % n == 0, f"ABFP needs K % n == 0, got K={K} n={n}"
    xb = x.reshape(x.shape[:-1] + (K // n, n))
    alpha = jnp.max(jnp.abs(xb), axis=-1)  # raw, per-vector
    gamma = _bf16(jnp.max(alpha, axis=-1, keepdims=True))
    gamma = jnp.where(gamma > 0, gamma, 1.0)
    smax = float(2 ** scale_bits - 1)
    code = jnp.clip(jnp.ceil(alpha / gamma * smax), 1.0, smax)
    alpha_hat = _bf16(code / smax * gamma)
    alpha_hat = jnp.where(alpha > 0, alpha_hat, 1.0)
    return alpha_hat, gamma


def abfp2_qdq(x, fmt, n: int, scale_bits: int = 8):
    """Two-level ABFP fake-quant: ABFP payload with 8-bit quantized scales.

    Identical to :func:`abfp_qdq` except the per-vector scale itself is
    stored as an unsigned ``scale_bits`` code against a per-row BF16
    second-level scale — the compression the paper defers to future work.
    """
    K = x.shape[-1]
    alpha, _ = abfp2_scales(x, n, scale_bits)
    xb = x.reshape(x.shape[:-1] + (K // n, n))
    a = alpha[..., None]
    if isinstance(fmt, F.IntFormat):
        y = int_qdq(xb, float(fmt.qmax) / a, fmt.bits)
    else:
        y = fp_qdq(xb, float(fmt.fmax) / a, fmt)
    return y.reshape(x.shape).astype(jnp.float32)


def static_int_qdq(x, alpha, bits: int):
    """Static-scale integer fake-quant from a calibrated clip range alpha.

    alpha is per-tensor (scalar) or per-channel over the last axis
    (shape (K,)).  s = qmax / alpha, Eqn (1).
    """
    qmax = float(2 ** (bits - 1) - 1)
    alpha = jnp.where(alpha > 0, alpha, 1.0)
    return int_qdq(x, qmax / alpha, bits)


def per_channel_max_weight_qdq(w, bits: int):
    """Per-output-channel max calibration for weights (paper §II-B-1).

    w: (dout, din); alpha = absmax over din per output row.
    """
    alpha = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    alpha = jnp.where(alpha > 0, alpha, 1.0)
    qmax = float(2 ** (bits - 1) - 1)
    return int_qdq(w, qmax / alpha, bits)
