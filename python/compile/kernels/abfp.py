"""Pallas kernel for Adaptive Block Floating Point fake-quantization.

This is the hot-spot of the simulator: ABFP QDQ runs on the input
activations *and* the weights of every matmul-bearing layer (Eqns 6-8).

TPU mapping (DESIGN.md §Hardware-Adaptation): ABFP's length-``n`` vector
scaling is itself a blocking scheme, so the BlockSpec tiles the scaled
(reduction) axis in steps of exactly ``n``: one grid step owns a
``(R, n)`` VMEM tile, computes the per-row absmax (a lane reduction on
the VPU), quantizes the payload and de-quantizes — all without an HBM
round-trip between Q and DQ.  ``n`` ∈ {64, 128} lines up with the
128-lane vector unit / MXU tile edge, which is why those vector lengths
are "free" on this hardware.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO through the Pallas
interpreter.  Numerics are identical; real-TPU performance is estimated
analytically in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats as F
from . import ref


def _abfp_block_kernel(x_ref, o_ref, *, fmt, n):
    """One grid step = one (R, c*n) tile holding c vectors per row.

    §Perf iteration 1: the original kernel used one n-chunk per grid step
    (tile (R, n), grid K/n); per-step dispatch overhead dominated for wide
    tensors (interpret-mode ratio 0.2x vs the jnp oracle at K=2048).
    Grouping c chunks per step amortizes the dispatch — and on real TPU
    amortizes the HBM→VMEM DMA — while the per-vector scale math is
    unchanged (bit-identical outputs; the in-tile reshape is free).
    """
    xt = x_ref[...]
    R, cn = xt.shape
    x = xt.reshape(R, cn // n, n)
    alpha = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # ABFP keeps scales in BF16 (paper §II-B-2).
    alpha = alpha.astype(jnp.bfloat16).astype(jnp.float32)
    alpha = jnp.where(alpha > 0, alpha, 1.0)
    if isinstance(fmt, F.IntFormat):
        qmax = float(fmt.qmax)
        s = qmax / alpha
        q = jnp.clip(jnp.round(x * s), -qmax, qmax)
        o_ref[...] = (q / s).astype(jnp.float32).reshape(R, cn)
    else:
        s = float(fmt.fmax) / alpha
        o_ref[...] = (
            (ref.fp_round(x * s, fmt) / s).astype(jnp.float32).reshape(R, cn)
        )


# Max n-chunks fused into one grid step / VMEM tile. 8 keeps the largest
# tile in the artifact matrix (2048 rows x 8*128 lanes) at 8 MiB  — within
# a double-buffered 16 MiB VMEM budget.
MAX_CHUNKS_PER_STEP = 8


def _chunk_group(k_chunks: int) -> int:
    """Largest power-of-two divisor of k_chunks, capped at MAX_CHUNKS_PER_STEP."""
    c = 1
    while c * 2 <= MAX_CHUNKS_PER_STEP and k_chunks % (c * 2) == 0:
        c *= 2
    return c


@functools.partial(jax.jit, static_argnames=("fmt", "n"))
def abfp_qdq_2d(x, fmt, n: int):
    """ABFP QDQ of a 2-D ``(R, K)`` array along the last axis, K % n == 0."""
    R, K = x.shape
    assert K % n == 0, f"ABFP kernel needs K % n == 0, got K={K} n={n}"
    c = _chunk_group(K // n)
    return pl.pallas_call(
        functools.partial(_abfp_block_kernel, fmt=fmt, n=n),
        out_shape=jax.ShapeDtypeStruct((R, K), jnp.float32),
        grid=(K // (c * n),),
        in_specs=[pl.BlockSpec((R, c * n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((R, c * n), lambda i: (0, i)),
        interpret=True,
    )(x)


def abfp_qdq(x, fmt, n: int):
    """ABFP QDQ along the last axis of an arbitrary-rank array."""
    shape = x.shape
    x2 = x.reshape((-1, shape[-1]))
    return abfp_qdq_2d(x2, fmt, n).reshape(shape)


# --- two-level scales (VS-Quant; paper §II-B-2 second-level quantization) --


def _abfp2_row_kernel(x_ref, o_ref, *, fmt, n, scale_bits):
    """One grid step = a full-row tile (RB, K): the second-level scale is a
    per-row reduction, so the whole row must live in one VMEM tile.  K is
    at most 4·d = 2048 in the artifact matrix, so a (128, 2048) f32 tile is
    1 MiB — well inside a double-buffered VMEM budget.
    """
    xt = x_ref[...]
    RB, K = xt.shape
    x = xt.reshape(RB, K // n, n)
    alpha = jnp.max(jnp.abs(x), axis=-1)  # (RB, K//n) raw
    gamma = jnp.max(alpha, axis=-1, keepdims=True)
    gamma = gamma.astype(jnp.bfloat16).astype(jnp.float32)
    gamma = jnp.where(gamma > 0, gamma, 1.0)
    smax = float(2 ** scale_bits - 1)
    code = jnp.clip(jnp.ceil(alpha / gamma * smax), 1.0, smax)
    # Reconstructed scales are BF16 like every ABFP scale (see ref.py).
    ah = (code / smax * gamma).astype(jnp.bfloat16).astype(jnp.float32)
    a = jnp.where(alpha > 0, ah, 1.0)[..., None]
    if isinstance(fmt, F.IntFormat):
        qmax = float(fmt.qmax)
        s = qmax / a
        q = jnp.clip(jnp.round(x * s), -qmax, qmax)
        o_ref[...] = (q / s).astype(jnp.float32).reshape(RB, K)
    else:
        s = float(fmt.fmax) / a
        o_ref[...] = (
            (ref.fp_round(x * s, fmt) / s).astype(jnp.float32).reshape(RB, K)
        )


def _row_block(rows: int, k: int) -> int:
    """Tile row count: largest power-of-two divisor of ``rows`` whose
    (rb, K) f32 tile stays within a 4 MiB budget (8 MiB double-buffered
    with the output tile — same envelope as the abfp kernel).

    §Perf L1 iteration 2: the original fixed 128-row cap left wide-R
    arrays split across many grid steps, and per-step dispatch dominated
    under interpret (4.4x slower than plain abfp at 2048x512).  Sizing
    the block from the VMEM budget collapses those to one or two steps;
    per-row numerics are independent of blocking, so outputs are
    bit-identical.
    """
    cap = max(1, (4 << 20) // (4 * k))
    rb = 1
    while rb * 2 <= cap and rows % (rb * 2) == 0:
        rb *= 2
    return rb


@functools.partial(jax.jit, static_argnames=("fmt", "n", "scale_bits"))
def abfp2_qdq_2d(x, fmt, n: int, scale_bits: int = 8):
    """Two-level ABFP QDQ of a 2-D ``(R, K)`` array along the last axis."""
    R, K = x.shape
    assert K % n == 0, f"ABFP kernel needs K % n == 0, got K={K} n={n}"
    rb = _row_block(R, K)
    return pl.pallas_call(
        functools.partial(
            _abfp2_row_kernel, fmt=fmt, n=n, scale_bits=scale_bits
        ),
        out_shape=jax.ShapeDtypeStruct((R, K), jnp.float32),
        grid=(R // rb,),
        in_specs=[pl.BlockSpec((rb, K), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, K), lambda i: (i, 0)),
        interpret=True,
    )(x)


def abfp2_qdq(x, fmt, n: int, scale_bits: int = 8):
    """Two-level ABFP QDQ along the last axis of an arbitrary-rank array."""
    shape = x.shape
    x2 = x.reshape((-1, shape[-1]))
    return abfp2_qdq_2d(x2, fmt, n, scale_bits).reshape(shape)
