"""Adam train-steps for FP32 pretraining and ABFP quantization-aware
training (paper §II-C).

QAT runs the *forward pass through the ABFP quantizers* with the PWL
estimator in the backward pass (Eqn 5) — wired by ``QuantWiring.ste``.
The optimizer state (m, v) is threaded through the artifact as explicit
inputs/outputs so the Rust training driver owns it; the step counter and
learning rate are runtime scalars, letting the driver implement any
schedule without recompilation.
"""

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

B1, B2, EPS = 0.9, 0.999, 1e-8


def adam_update(params, m, v, grads, step, lr):
    """One Adam step over a dict of tensors; returns (params', m', v')."""
    t = step  # f32 scalar, 1-based
    out_p, out_m, out_v = {}, {}, {}
    bc1 = 1.0 - jnp.power(B1, t)
    bc2 = 1.0 - jnp.power(B2, t)
    for k in params:
        g = grads[k]
        m2 = B1 * m[k] + (1.0 - B1) * g
        v2 = B2 * v[k] + (1.0 - B2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        out_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + EPS)
        out_m[k] = m2
        out_v[k] = v2
    return out_p, out_m, out_v


#: Parameters excluded from optimization.  The log-normal outlier gains
#: (`emb_gain`, LN gains) simulate the per-channel magnitude spread that
#: billion-parameter LLMs develop over full pretraining; at our scale a
#: few hundred Adam steps would regress them toward uniform, so they are
#: frozen — they model an *end state*, not something to learn away
#: (DESIGN.md §1 substitution table).
FROZEN_SUFFIXES = ("emb_gain", "ln1_g", "ln2_g")


def is_frozen(name: str) -> bool:
    return name.endswith(FROZEN_SUFFIXES)


def make_train_step(loss_fn: Callable, param_names: List[str]):
    """Build a train-step over flat param lists (manifest order).

    loss_fn(params_dict, *data) -> scalar loss.
    Returns fn(params_list, m_list, v_list, step, lr, *data)
             -> (new_params..., new_m..., new_v..., loss) as a flat tuple.
    """

    def step_fn(plist, mlist, vlist, step, lr, *data):
        params = dict(zip(param_names, plist))
        m = dict(zip(param_names, mlist))
        v = dict(zip(param_names, vlist))
        loss, grads = jax.value_and_grad(
            lambda pp: loss_fn(pp, *data)
        )(params)
        for k in param_names:
            if is_frozen(k):
                grads[k] = jnp.zeros_like(grads[k])
        p2, m2, v2 = adam_update(params, m, v, grads, step, lr)
        flat = (
            [p2[k] for k in param_names]
            + [m2[k] for k in param_names]
            + [v2[k] for k in param_names]
            + [loss]
        )
        return tuple(flat)

    return step_fn
