"""Numeric format descriptors for INT-FP-QSim.

The paper (§II-A) fixes weights at 4 bits and sweeps activations over
INT4 / INT8 / FP4 (E2M1, E1M2) / FP8 (E4M3).  This module is the single
source of truth for those formats on the Python side; the Rust mirror
(`rust/src/formats/`) is validated bit-exactly against golden tables
emitted from here (see aot.py --goldens).

Conventions (documented divergences from the paper's notation):

* Integer quantization is *symmetric signed* with
  ``qmax = 2**(bits-1) - 1`` and clip range ``[-qmax, qmax]``.  Eqn (1)-(2)
  of the paper write ``(2^b - 1)/alpha`` with clip bounds ``±2^b - 1``,
  which would overflow a signed b-bit payload; every implementation the
  paper builds on (TensorRT pytorch-quantization [7]) uses the symmetric
  convention, so we follow that.
* Low-precision float formats carry **no inf** and saturate to ``fmax``
  (the FP8-paper convention [13] that the paper adopts).  NaN never
  arises because quantizer inputs are finite by construction.
* E1M2 has exponent bias ``2**(e-1) - 1 = 0``; its value grid
  ``±{0, .5, 1, 1.5, 2, 2.5, 3, 3.5}`` is near-uniform, which is why the
  paper finds E1M2 ≈ INT4 (Table II).
"""

from dataclasses import dataclass
from typing import List, Union


@dataclass(frozen=True)
class IntFormat:
    """Symmetric signed integer format with ``bits`` total bits."""

    bits: int

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def name(self) -> str:
        return f"int{self.bits}"


@dataclass(frozen=True)
class FpFormat:
    """Miniature float: 1 sign bit, ``e`` exponent bits, ``m`` mantissa bits.

    No inf encoding; the top of the grid is used for normal values and
    quantization saturates there.  Subnormals are representable.
    ``nan_reserved`` models the FP8-paper E4M3 convention [13] where the
    all-ones code point (top exponent, full mantissa) encodes NaN, so the
    largest finite value drops one mantissa step (448 instead of 480).
    """

    e: int
    m: int
    nan_reserved: bool = False

    @property
    def bias(self) -> int:
        return 2 ** (self.e - 1) - 1

    @property
    def emin(self) -> int:
        """Exponent of the smallest *normal* binade."""
        return 1 - self.bias

    @property
    def emax(self) -> int:
        return (2 ** self.e - 1) - self.bias

    @property
    def fmax(self) -> float:
        """Largest finite magnitude: top binade, full mantissa (minus one
        mantissa step if the all-ones code point is reserved for NaN)."""
        top = 2.0 - 2.0 ** (-self.m)
        if self.nan_reserved:
            top -= 2.0 ** (-self.m)
        return float(2.0 ** self.emax * top)

    @property
    def smallest_subnormal(self) -> float:
        return float(2.0 ** self.emin * 2.0 ** (-self.m))

    @property
    def name(self) -> str:
        return f"e{self.e}m{self.m}"

    def grid(self) -> List[float]:
        """Every non-negative representable value, ascending.

        Used by tests (RNE onto the grid must equal the kernel) and by the
        golden tables consumed by the Rust mirror.
        """
        vals = {0.0}
        # subnormals: 2^emin * k/2^m, k in [1, 2^m - 1]
        for k in range(1, 2 ** self.m):
            vals.add(2.0 ** self.emin * k / 2.0 ** self.m)
        # normals: 2^E * (1 + k/2^m)
        for efield in range(1, 2 ** self.e):
            E = efield - self.bias
            for k in range(2 ** self.m):
                if (
                    self.nan_reserved
                    and efield == 2 ** self.e - 1
                    and k == 2 ** self.m - 1
                ):
                    continue  # all-ones code point is NaN, not a value
                vals.add(2.0 ** E * (1.0 + k / 2.0 ** self.m))
        return sorted(vals)


Format = Union[IntFormat, FpFormat]

INT4 = IntFormat(4)
INT8 = IntFormat(8)
E2M1 = FpFormat(2, 1)
E1M2 = FpFormat(1, 2)
E4M3 = FpFormat(4, 3, nan_reserved=True)  # OCP/[13] convention, fmax = 448

BY_NAME = {f.name: f for f in (INT4, INT8, E2M1, E1M2, E4M3)}


def parse(name: str) -> Format:
    """Parse a format name (``int4``, ``e4m3``, ...) to a descriptor."""
    if name in BY_NAME:
        return BY_NAME[name]
    if name.startswith("int"):
        return IntFormat(int(name[3:]))
    if name.startswith("e") and "m" in name:
        e, m = name[1:].split("m")
        return FpFormat(int(e), int(m))
    raise ValueError(f"unknown format {name!r}")
