"""L1/L2 performance profiling (§Perf):

* kernel vs oracle wallclock under interpret=True (target: kernel within
  2x of the pure-jnp reference — interpret-mode wallclock is NOT a TPU
  proxy, so the structural VMEM/MXU analysis below is the primary
  deliverable);
* analytic VMEM footprint + MXU utilization estimate for the ABFP
  BlockSpec on a real TPU (v4 numbers), recorded in EXPERIMENTS.md §Perf;
* lowered-HLO cost analysis of representative L2 artifacts.

Run: cd python && python -m compile.bench_kernels
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import formats as F
from .kernels import abfp, ref

VMEM_BYTES = 16 * 1024 * 1024  # v4 per-core VMEM
VPU_LANES = 128


def timeit(fn, *args, iters=10):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def kernel_vs_ref():
    print("== ABFP kernel vs pure-jnp oracle (interpret=True, CPU) ==")
    rs = np.random.RandomState(0)
    for (rows, k) in [(512, 512), (512, 2048), (2048, 512)]:
        x = jnp.asarray(rs.randn(rows, k).astype("float32"))
        for fmt in (F.INT4, F.E4M3):
            for n in (64, 128):
                t_k = timeit(
                    jax.jit(lambda v: abfp.abfp_qdq_2d(v, fmt, n),
                            static_argnums=()), x)
                t_r = timeit(
                    jax.jit(lambda v: ref.abfp_qdq(v, fmt, n)), x)
                print(
                    f"  {rows}x{k} {fmt.name} n={n}: kernel {t_k*1e3:7.2f} ms"
                    f"  ref {t_r*1e3:7.2f} ms  ratio {t_r/t_k:5.2f}x"
                )


def abfp2_vs_abfp():
    print("\n== two-level (abfp2) kernel vs plain abfp, and vs its oracle ==")
    rs = np.random.RandomState(1)
    for (rows, k) in [(512, 2048), (2048, 512)]:
        x = jnp.asarray(rs.randn(rows, k).astype("float32"))
        t1 = timeit(jax.jit(lambda v: abfp.abfp_qdq_2d(v, F.INT4, 64)), x)
        t2 = timeit(jax.jit(lambda v: abfp.abfp2_qdq_2d(v, F.INT4, 64)), x)
        t2r = timeit(jax.jit(lambda v: ref.abfp2_qdq(v, F.INT4, 64)), x)
        print(
            f"  {rows}x{k}: abfp {t1*1e3:7.2f} ms  abfp2 {t2*1e3:7.2f} ms"
            f"  (x{t2/t1:4.2f})  abfp2-ref {t2r*1e3:7.2f} ms"
            f"  ratio {t2r/t2:4.2f}x"
        )


def vmem_mxu_estimate():
    print("\n== TPU structural estimate for the ABFP BlockSpec ==")
    print("(tile = (R, n) f32 in VMEM; per-tile work = absmax reduce +")
    print(" elementwise QDQ, both on the 128-lane VPU)")
    for (rows, k, n) in [(512, 512, 64), (512, 2048, 64), (512, 2048, 128),
                         (2048, 2048, 128)]:
        tile_bytes = rows * n * 4 * 2  # in + out tile double-buffered
        grid = k // n
        fits = tile_bytes <= VMEM_BYTES
        # VPU utilization: lanes used per vector op on the last axis
        lanes = min(n, VPU_LANES) / VPU_LANES
        print(
            f"  ({rows},{k}) n={n}: grid={grid:3d} tiles, "
            f"tile {tile_bytes/1024:7.0f} KiB (double-buffered) "
            f"{'fits' if fits else 'EXCEEDS'} VMEM, lane util {lanes:.0%}"
        )
    print("  -> n in {64,128} keeps every tile VMEM-resident with 50-100%")
    print("     lane utilization; Q and DQ fuse in-tile (no HBM round trip),")
    print("     so the kernel is HBM-bandwidth-bound at ~2 bytes/elem moved")
    print("     per 2 flops: the same roofline class as the paper's fused")
    print("     fake-quant CUDA kernels (DESIGN.md §Hardware-Adaptation).")


def hlo_cost():
    print("\n== L2 lowered-HLO cost analysis ==")
    from . import aot, registry as R

    for aid in [
        ("sim-opt-125m", "eval", "fp32"),
        ("sim-opt-125m", "eval", "abfp_w4a4_n64"),
        ("sim-opt-2.7b", "eval", "abfp_w4a4_n64"),
        ("sim-opt-125m", "train", "qat_w4a4_n64"),
    ]:
        adef = R.ArtifactDef(*aid)
        fn, specs, _, _ = aot.build_artifact(adef)
        compiled = jax.jit(fn).lower(*specs).compile()
        try:
            cost = compiled.cost_analysis()
            flops = cost.get("flops", float("nan"))
            bytes_ = cost.get("bytes accessed", float("nan"))
            print(
                f"  {adef.id}: {flops/1e9:8.3f} GFLOP, "
                f"{bytes_/1e6:8.1f} MB accessed, "
                f"arith intensity {flops/max(bytes_,1):5.1f} flop/B"
            )
        except Exception as e:  # cost analysis availability varies
            print(f"  {adef.id}: cost analysis unavailable ({e})")


if __name__ == "__main__":
    kernel_vs_ref()
    abfp2_vs_abfp()
    vmem_mxu_estimate()
    hlo_cost()
