"""ViT-style image classifier (ImageNet ViT-L/16 & /32 stand-ins).

Patchify → linear patch embed (unquantized, mirroring the common
first-layer-in-high-precision practice) → [CLS] + learned positions →
bidirectional encoder with quantized block linears → CLS head.

sim-vit-16 uses patch 4 on 32×32 images, sim-vit-32 patch 8 — the same
4× patch-area ratio as ViT-L/16 vs ViT-L/32.
"""

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from . import common as C


def param_specs(cfg: C.ArchCfg) -> List[Tuple[str, Tuple[int, ...], str]]:
    pdim = cfg.patch * cfg.patch * cfg.channels
    specs = [
        ("patch_w", (cfg.d, pdim), "normal"),
        ("patch_b", (cfg.d,), "zeros"),
        ("cls_tok", (cfg.d,), "normal"),
        ("pos_emb", (cfg.n_patches + 1, cfg.d), "normal"),
        ("emb_gain", (cfg.d,), "lognormal"),
    ]
    for li in range(cfg.L):
        specs += C.block_param_specs(li, cfg.d)
    specs += [
        ("lnf_g", (cfg.d,), "ones"),
        ("lnf_b", (cfg.d,), "zeros"),
        ("head_w", (cfg.classes, cfg.d), "normal"),
        ("head_b", (cfg.classes,), "zeros"),
    ]
    return specs


def patchify(images, patch: int):
    """(B, H, W, C) → (B, n_patches, patch*patch*C)."""
    B, H, W, Ch = images.shape
    ph, pw = H // patch, W // patch
    x = images.reshape(B, ph, patch, pw, patch, Ch)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, ph * pw, patch * patch * Ch)


def forward(
    p: Dict[str, jnp.ndarray],
    images,  # (B, H, W, C) f32
    cfg: C.ArchCfg,
    wiring: C.QuantWiring,
    sites: Dict[str, C.SiteInputs],
    capture: Optional[list] = None,
):
    """Returns class logits (B, classes)."""
    B = images.shape[0]
    x = patchify(images, cfg.patch) @ p["patch_w"].T + p["patch_b"]
    cls = jnp.broadcast_to(p["cls_tok"][None, None], (B, 1, cfg.d))
    x = jnp.concatenate([cls, x], axis=1)
    x = (x + p["pos_emb"][None]) * p["emb_gain"]
    for li in range(cfg.L):
        x = C.block(x, p, li, cfg, wiring, sites, causal=False, capture=capture)
    x = C.layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x[:, 0] @ p["head_w"].T + p["head_b"]  # CLS head, unquantized


def eval_logits(p, images, cfg, wiring, sites):
    return (forward(p, images, cfg, wiring, sites),)


def cls_loss(p, images, labels, cfg, wiring, sites):
    logits = forward(p, images, cfg, wiring, sites)
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    gold = jnp.take_along_axis(z, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def capture_acts(p, images, cfg):
    cap: list = []
    logits = forward(p, images, cfg, C.FP32, {}, capture=cap)
    assert [n for (n, _) in cap] == C.all_site_names(cfg)
    # _anchor: keeps the head/lnf params alive (see opt.capture_acts).
    return tuple(t for (_, t) in cap) + (jnp.mean(logits),)
