"""Shared transformer building blocks with quantizer-wrapped linears.

This is the L2 analog of the paper's layer replacement (§III): every
weight-bearing matmul in an encoder/decoder block goes through
``qlinear``, which applies the weight quantizer f_q^w, the
input-activation quantizer f_q^x and (optionally) the output quantizer
f_q^y around a high-precision matmul — Eqns (6)-(9) exactly.

Scope notes (mirroring the paper's setup and the SQ/GPTQ/RPTQ reference
implementations):
  * embeddings, the patch-embed conv, LM/classifier heads, and the
    parameter-free attention BMMs (QK^T, PV) stay in high precision;
  * output activations are left unquantized in all experiments (§IV:
    "we do not explore the impact of low-precision output quantization");
    f_q^y support exists for the photonics-hardware use case.
"""

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from .. import quantizers as Q


@dataclass(frozen=True)
class ArchCfg:
    """Static architecture + workload shape of one simulated model."""

    name: str
    arch: str  # opt | bert | vit
    vocab: int
    d: int
    L: int
    heads: int
    seq: int
    batch: int
    # role metadata: which paper checkpoint this model stands in for
    stands_for: str = ""
    task: str = "lm"  # lm | span_qa | image_cls
    # vit-specific
    image: int = 0
    patch: int = 0
    channels: int = 3
    classes: int = 0

    @property
    def d_ff(self) -> int:
        return 4 * self.d

    @property
    def head_dim(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads

    @property
    def n_patches(self) -> int:
        assert self.arch == "vit"
        return (self.image // self.patch) ** 2


@dataclass(frozen=True)
class QuantWiring:
    """How every quantized site in the model is wired for one artifact.

    ``layer_overrides`` implements per-layer mixed precision — the feature
    the paper's §VI lists as unsupported future work ("INT-FP-QSim
    currently does not support specification of different quantizers for
    different layers").  Each entry is ``(layer_index, QuantWiring)``;
    negative indices count from the back (``-1`` = last block), so one
    config serves models of different depth.  Overrides replace the
    wq/aq/oq specs for that block while inheriting the parent's
    ``smooth``/``ste`` flags (those are model-global wiring decisions).
    """

    wq: Q.QuantSpec = Q.NONE
    aq: Q.QuantSpec = Q.NONE
    oq: Q.QuantSpec = Q.NONE  # f_q^y; identity in all paper experiments
    smooth: bool = False  # SmoothQuant per-channel input vectors
    ste: bool = False  # QAT: PWL estimator around every QDQ
    layer_overrides: Tuple["Tuple[int, QuantWiring]", ...] = ()

    def for_layer(self, li: int, L: int) -> "QuantWiring":
        """Effective wiring for block ``li`` of an ``L``-block model."""
        for idx, w in self.layer_overrides:
            if idx % L == li % L:
                return QuantWiring(
                    wq=w.wq, aq=w.aq, oq=w.oq,
                    smooth=self.smooth, ste=self.ste,
                )
        return self

    def describe(self) -> dict:
        d = {
            "wq": self.wq.describe(),
            "aq": self.aq.describe(),
            "oq": self.oq.describe(),
            "smooth": self.smooth,
            "ste": self.ste,
        }
        if self.layer_overrides:
            d["layer_overrides"] = [
                [idx, w.describe()] for idx, w in self.layer_overrides
            ]
        return d


FP32 = QuantWiring()

# Quantized sites per transformer block, with their input dims (×d).
SITE_NAMES = ("qkv", "attn_out", "fc1", "fc2")


def site_in_dim(site: str, d: int) -> int:
    return 4 * d if site == "fc2" else d


@dataclass
class SiteInputs:
    """Runtime inputs feeding one site's quantizers (may be None)."""

    smooth: Optional[jnp.ndarray] = None  # (din,) SmoothQuant 1/s vector
    alpha: Optional[jnp.ndarray] = None  # scalar or (din,) activation clip


def qlinear(
    x,
    w,
    b,
    wiring: QuantWiring,
    site: Optional[SiteInputs] = None,
    capture: Optional[list] = None,
    capture_name: str = "",
):
    """Quantizer-wrapped linear: y = f_q^x(x·smooth) @ f_q^w(w)^T + b.

    x: (..., din), w: (dout, din).  ``capture`` collects the raw (pre-
    quantizer, post-smoothing-site placement but *before* smoothing is
    applied — the calibrator wants the raw tensor) activations for the
    Rust calibration engine.
    """
    si = site or SiteInputs()
    if capture is not None:
        capture.append((capture_name, x.reshape((-1, x.shape[-1]))))
    if si.smooth is not None:
        x = x * si.smooth
    xq = Q.apply(x, wiring.aq, alpha=si.alpha, ste=wiring.ste)
    wq = Q.apply(w, wiring.wq, ste=wiring.ste)
    y = xq @ wq.T + b
    return Q.apply(y, wiring.oq) if wiring.oq.kind != "none" else y


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def attention(q, k, v, heads: int, causal: bool):
    """Multi-head attention over (B, S, d) projections, fp32 internals."""
    B, S, d = q.shape
    hd = d // heads

    def split(t):
        return t.reshape(B, S, heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(hd))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(B, S, d)


def block(
    x,
    p: Dict[str, jnp.ndarray],
    li: int,
    cfg: ArchCfg,
    wiring: QuantWiring,
    sites: Dict[str, SiteInputs],
    causal: bool,
    capture: Optional[list] = None,
):
    """Pre-LN transformer block with quantized qkv/out/fc1/fc2 linears."""
    wiring = wiring.for_layer(li, cfg.L)

    def P(n):
        return p[f"l{li}.{n}"]

    def S(site):
        return sites.get(f"l{li}.{site}")

    h = layer_norm(x, P("ln1_g"), P("ln1_b"))
    qkv = qlinear(
        h, P("wqkv"), P("bqkv"), wiring, S("qkv"), capture, f"l{li}.qkv"
    )
    q, k, v = jnp.split(qkv, 3, axis=-1)
    a = attention(q, k, v, cfg.heads, causal)
    a = qlinear(
        a, P("wo"), P("bo"), wiring, S("attn_out"), capture, f"l{li}.attn_out"
    )
    x = x + a
    h = layer_norm(x, P("ln2_g"), P("ln2_b"))
    h = qlinear(
        h, P("wfc1"), P("bfc1"), wiring, S("fc1"), capture, f"l{li}.fc1"
    )
    h = jnp.maximum(h, 0.0)  # OPT uses ReLU
    h = qlinear(
        h, P("wfc2"), P("bfc2"), wiring, S("fc2"), capture, f"l{li}.fc2"
    )
    return x + h


def block_param_specs(li: int, d: int) -> List[Tuple[str, Tuple[int, ...], str]]:
    """(name, shape, init) triples for one block; init ∈ {normal, zeros, ones}."""
    dff = 4 * d
    return [
        (f"l{li}.ln1_g", (d,), "lngain"),
        (f"l{li}.ln1_b", (d,), "zeros"),
        (f"l{li}.wqkv", (3 * d, d), "normal"),
        (f"l{li}.bqkv", (3 * d,), "zeros"),
        (f"l{li}.wo", (d, d), "residual"),
        (f"l{li}.bo", (d,), "zeros"),
        (f"l{li}.ln2_g", (d,), "lngain"),
        (f"l{li}.ln2_b", (d,), "zeros"),
        (f"l{li}.wfc1", (dff, d), "normal"),
        (f"l{li}.bfc1", (dff,), "zeros"),
        (f"l{li}.wfc2", (d, dff), "residual"),
        (f"l{li}.bfc2", (d,), "zeros"),
    ]


def all_site_names(cfg: ArchCfg) -> List[str]:
    """Every quantized site in model order, as ``l{i}.{site}`` ids."""
    return [f"l{i}.{s}" for i in range(cfg.L) for s in SITE_NAMES]


def site_dims(cfg: ArchCfg) -> Dict[str, int]:
    return {
        f"l{i}.{s}": site_in_dim(s, cfg.d)
        for i in range(cfg.L)
        for s in SITE_NAMES
    }
