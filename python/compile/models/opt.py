"""OPT-style decoder language model (stand-in for OPT 125M…2.7B).

Architecture follows OPT: learned positional embeddings, pre-LN blocks,
ReLU FFN, tied LM head.  One deliberate addition, ``emb_gain``: a
per-channel log-normal gain on the embedding output.  Billion-parameter
LLMs develop a handful of high-magnitude activation channels that make
per-tensor activation quantization collapse (the motivation for
SmoothQuant/RPTQ); models at our simulation scale trained for a few
hundred steps do not develop them organically, so the gain injects the
same per-channel magnitude spread into the residual stream.  It is a
trained parameter initialized log-normally (DESIGN.md §1 substitution
table).  The Codegen stand-ins reuse this module with a different vocab
and corpus.
"""

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from . import common as C


def param_specs(cfg: C.ArchCfg) -> List[Tuple[str, Tuple[int, ...], str]]:
    specs = [
        ("tok_emb", (cfg.vocab, cfg.d), "normal"),
        ("pos_emb", (cfg.seq, cfg.d), "normal"),
        ("emb_gain", (cfg.d,), "lognormal"),
    ]
    for li in range(cfg.L):
        specs += C.block_param_specs(li, cfg.d)
    specs += [("lnf_g", (cfg.d,), "ones"), ("lnf_b", (cfg.d,), "zeros")]
    return specs


def forward(
    p: Dict[str, jnp.ndarray],
    tokens,  # (B, S) int32
    cfg: C.ArchCfg,
    wiring: C.QuantWiring,
    sites: Dict[str, C.SiteInputs],
    capture: Optional[list] = None,
):
    """Returns logits (B, S, vocab)."""
    B, S = tokens.shape
    x = p["tok_emb"][tokens] * p["emb_gain"] + p["pos_emb"][None, :S]
    for li in range(cfg.L):
        x = C.block(x, p, li, cfg, wiring, sites, causal=True, capture=capture)
    x = C.layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["tok_emb"].T  # tied head, unquantized


def nll_sum(logits, tokens):
    """Sum of next-token negative log-likelihoods over the batch.

    Positions 0..S-2 predict tokens 1..S-1; returns a scalar so the Rust
    evaluator can aggregate exact corpus PPL across batches.
    """
    z = logits[:, :-1]
    z = z - jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
    logp = z - lse
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll)


def eval_nll(p, tokens, cfg, wiring, sites):
    """Eval artifact body: (sum_nll,)."""
    return (nll_sum(forward(p, tokens, cfg, wiring, sites), tokens),)


def eval_logits(p, tokens, cfg, wiring, sites):
    """Logits artifact body for greedy decoding (Codegen Pass@1)."""
    return (forward(p, tokens, cfg, wiring, sites),)


def capture_acts(p, tokens, cfg):
    """Capture artifact body: every site's raw input activations, in
    ``common.all_site_names`` order, each flattened to (B*S, din).

    The trailing ``_anchor`` scalar touches the full forward pass so XLA
    cannot prune "unused" tail parameters (lnf, last-layer fc2) — the
    artifact's parameter list must match the manifest exactly.
    """
    cap: list = []
    logits = forward(p, tokens, cfg, C.FP32, {}, capture=cap)
    names = C.all_site_names(cfg)
    got = [t for (_, t) in cap]
    assert [n for (n, _) in cap] == names, "site order mismatch"
    return tuple(got) + (jnp.mean(logits),)
