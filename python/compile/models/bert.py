"""BERT-style encoder with a span-extraction QA head (SQuAD stand-in).

Bidirectional pre-LN encoder over [CLS] question [SEP] passage token
streams; a 2-output linear head produces start/end logits.  The head and
embeddings stay unquantized (common.py scope notes).
"""

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from . import common as C


def param_specs(cfg: C.ArchCfg) -> List[Tuple[str, Tuple[int, ...], str]]:
    specs = [
        ("tok_emb", (cfg.vocab, cfg.d), "normal"),
        ("pos_emb", (cfg.seq, cfg.d), "normal"),
        ("emb_gain", (cfg.d,), "lognormal"),
    ]
    for li in range(cfg.L):
        specs += C.block_param_specs(li, cfg.d)
    specs += [
        ("lnf_g", (cfg.d,), "ones"),
        ("lnf_b", (cfg.d,), "zeros"),
        ("span_w", (2, cfg.d), "normal"),
        ("span_b", (2,), "zeros"),
    ]
    return specs


def forward(
    p: Dict[str, jnp.ndarray],
    tokens,  # (B, S) int32
    cfg: C.ArchCfg,
    wiring: C.QuantWiring,
    sites: Dict[str, C.SiteInputs],
    capture: Optional[list] = None,
):
    """Returns (start_logits, end_logits), each (B, S)."""
    B, S = tokens.shape
    x = p["tok_emb"][tokens] * p["emb_gain"] + p["pos_emb"][None, :S]
    for li in range(cfg.L):
        x = C.block(x, p, li, cfg, wiring, sites, causal=False, capture=capture)
    x = C.layer_norm(x, p["lnf_g"], p["lnf_b"])
    span = x @ p["span_w"].T + p["span_b"]  # (B, S, 2), unquantized head
    return span[..., 0], span[..., 1]


def eval_spans(p, tokens, cfg, wiring, sites):
    """Eval artifact body: (start_logits, end_logits)."""
    return forward(p, tokens, cfg, wiring, sites)


def span_loss(p, tokens, starts, ends, cfg, wiring, sites):
    """Mean CE over gold start/end positions; starts/ends (B,) int32."""
    sl, el = forward(p, tokens, cfg, wiring, sites)

    def ce(logits, tgt):
        z = logits - jnp.max(logits, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
        gold = jnp.take_along_axis(z, tgt[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    return 0.5 * (ce(sl, starts) + ce(el, ends))


def capture_acts(p, tokens, cfg):
    cap: list = []
    sl, el = forward(p, tokens, cfg, C.FP32, {}, capture=cap)
    assert [n for (n, _) in cap] == C.all_site_names(cfg)
    # _anchor: keeps the head/lnf params alive (see opt.capture_acts).
    return tuple(t for (_, t) in cap) + (jnp.mean(sl) + jnp.mean(el),)
