"""The artifact matrix: every simulated model and quantizer configuration.

This is the single source of truth consumed by aot.py (to lower HLO
artifacts), by the manifest (read by the Rust coordinator), and by the
tests.  Model sizes are scaled-down stand-ins for the paper's
checkpoints (DESIGN.md §1); every width is a multiple of 128 so both
ABFP vector lengths (n=64, n=128) tile the reduction axes exactly.
"""

from dataclasses import dataclass
from typing import Dict, List

from . import formats as F
from . import quantizers as Q
from .models import common as C

VOCAB = 512
CODE_VOCAB = 64
SEQ = 64
BATCH = 8

MODELS: Dict[str, C.ArchCfg] = {}


def _add(cfg: C.ArchCfg):
    MODELS[cfg.name] = cfg


# OPT family — Wikitext2 PPL stand-ins (paper Tables I-VIII, X).
_add(C.ArchCfg("sim-opt-125m", "opt", VOCAB, 128, 2, 2, SEQ, BATCH,
               stands_for="OPT 125M", task="lm"))
_add(C.ArchCfg("sim-opt-350m", "opt", VOCAB, 256, 2, 4, SEQ, BATCH,
               stands_for="OPT 350M", task="lm"))
_add(C.ArchCfg("sim-opt-1.3b", "opt", VOCAB, 384, 3, 6, SEQ, BATCH,
               stands_for="OPT 1.3B", task="lm"))
_add(C.ArchCfg("sim-opt-2.7b", "opt", VOCAB, 512, 3, 8, SEQ, BATCH,
               stands_for="OPT 2.7B", task="lm"))
# Codegen family — HumanEval Pass@1 stand-ins (expression corpus).
_add(C.ArchCfg("sim-codegen-2b", "opt", CODE_VOCAB, 256, 2, 4, SEQ, BATCH,
               stands_for="Codegen 2B", task="codegen"))
_add(C.ArchCfg("sim-codegen-6b", "opt", CODE_VOCAB, 384, 3, 6, SEQ, BATCH,
               stands_for="Codegen 6B", task="codegen"))
# BERT family — SQuAD span-F1 stand-ins.
_add(C.ArchCfg("sim-bert-base", "bert", VOCAB, 128, 2, 2, SEQ, BATCH,
               stands_for="BERT-base", task="span_qa"))
_add(C.ArchCfg("sim-bert-large", "bert", VOCAB, 256, 3, 4, SEQ, BATCH,
               stands_for="BERT-large", task="span_qa"))
# ViT family — ImageNet accuracy stand-ins.
_add(C.ArchCfg("sim-vit-16", "vit", 0, 128, 2, 2, 0, 16,
               stands_for="ViT-large-16", task="image_cls",
               image=32, patch=4, channels=3, classes=16))
_add(C.ArchCfg("sim-vit-32", "vit", 0, 128, 2, 2, 0, 16,
               stands_for="ViT-large-32", task="image_cls",
               image=32, patch=8, channels=3, classes=16))


# --- quantizer configurations ---------------------------------------------

def _w(wiring: C.QuantWiring) -> C.QuantWiring:
    return wiring


QUANT_CONFIGS: Dict[str, C.QuantWiring] = {
    "fp32": C.FP32,
    # ABFP, dynamic per-vector scales; smooth inputs allow ABFP-SQ reuse.
    "abfp_w4a4_n64": C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), smooth=True),
    "abfp_w4a4_n128": C.QuantWiring(Q.abfp(F.INT4, 128), Q.abfp(F.INT4, 128), smooth=True),
    "abfp_w4a8_n64": C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64), smooth=True),
    "abfp_w4a8_n128": C.QuantWiring(Q.abfp(F.INT4, 128), Q.abfp(F.INT8, 128), smooth=True),
    "abfp_e2m1_n64": C.QuantWiring(Q.abfp(F.E2M1, 64), Q.abfp(F.E2M1, 64), smooth=True),
    "abfp_e1m2_n64": C.QuantWiring(Q.abfp(F.E1M2, 64), Q.abfp(F.E1M2, 64), smooth=True),
    "abfp_e1m2_n128": C.QuantWiring(Q.abfp(F.E1M2, 128), Q.abfp(F.E1M2, 128), smooth=True),
    "abfp_w4ae4m3_n64": C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.E4M3, 64), smooth=True),
    # Static MSE calibration: per-channel max weights (in-graph), runtime
    # per-tensor activation clip ranges found by the Rust MSE calibrator.
    "mse_w4a4": C.QuantWiring(Q.w_pcmax_int(4), Q.static_int(4)),
    "mse_w4a8": C.QuantWiring(Q.w_pcmax_int(4), Q.static_int(8)),
    # RPTQ: cluster-wise activation scales expressed per-channel.
    "rptq_w4a4": C.QuantWiring(Q.w_pcmax_int(4), Q.static_int_pc(4)),
    "rptq_w4a8": C.QuantWiring(Q.w_pcmax_int(4), Q.static_int_pc(8)),
    # QAT (train-step artifacts only): ABFP forward, PWL backward.
    "qat_w4a4_n64": C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), ste=True),
    "qat_w4a4_n128": C.QuantWiring(Q.abfp(F.INT4, 128), Q.abfp(F.INT4, 128), ste=True),
    "qat_w4a8_n64": C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64), ste=True),
    "qat_w4a8_n128": C.QuantWiring(Q.abfp(F.INT4, 128), Q.abfp(F.INT8, 128), ste=True),
    # --- extensions beyond the paper's experiments (DESIGN.md §Extensions) --
    # Two-level scales (VS-Quant; §II-B-2 "second-level quantization for
    # the scale factors could be utilized to achieve further compression").
    "abfp2_w4a4_n64": C.QuantWiring(Q.abfp2(F.INT4, 64), Q.abfp2(F.INT4, 64), smooth=True),
    "abfp2_w4a8_n64": C.QuantWiring(Q.abfp2(F.INT4, 64), Q.abfp2(F.INT8, 64), smooth=True),
    # Output quantization f_q^y (Eqn 9; the photonics-hardware case §III —
    # every paper experiment leaves outputs in FP16).
    "abfp_w4a4_o8_n64": C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64), smooth=True),
    "abfp_w4a4_oe4m3_n64": C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), Q.abfp(F.E4M3, 64), smooth=True),
    "abfp_w4a8_o8_n64": C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64), Q.abfp(F.INT8, 64), smooth=True),
    # Per-layer mixed precision (§VI lists this as unsupported future work):
    # boundary blocks (first + last) run at higher activation / weight
    # precision, interior blocks at W4A4 — the standard mixed recipe.
    "mixed_a8_boundary_n64": C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), smooth=True,
        layer_overrides=(
            (0, C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64))),
            (-1, C.QuantWiring(Q.abfp(F.INT4, 64), Q.abfp(F.INT8, 64))),
        )),
    "mixed_w8a8_boundary_n64": C.QuantWiring(
        Q.abfp(F.INT4, 64), Q.abfp(F.INT4, 64), smooth=True,
        layer_overrides=(
            (0, C.QuantWiring(Q.abfp(F.INT8, 64), Q.abfp(F.INT8, 64))),
            (-1, C.QuantWiring(Q.abfp(F.INT8, 64), Q.abfp(F.INT8, 64))),
        )),
}


@dataclass(frozen=True)
class ArtifactDef:
    model: str
    purpose: str  # eval | eval_logits | capture | train
    quant: str  # key into QUANT_CONFIGS

    @property
    def id(self) -> str:
        return f"{self.model}/{self.purpose}_{self.quant}"


OPT_EVAL_CONFIGS = [
    "fp32",
    "abfp_w4a4_n64", "abfp_w4a4_n128",
    "abfp_w4a8_n64", "abfp_w4a8_n128",
    "abfp_e2m1_n64", "abfp_e1m2_n64", "abfp_e1m2_n128",
    "abfp_w4ae4m3_n64",
    "mse_w4a4", "mse_w4a8",
    "rptq_w4a4", "rptq_w4a8",
]
SMALL_EVAL_CONFIGS = ["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64"]
OPT_TRAIN_CONFIGS = [
    "fp32", "qat_w4a4_n64", "qat_w4a4_n128", "qat_w4a8_n64", "qat_w4a8_n128",
]
# Extension ablations run on a small/large model pair (not the full OPT
# family) to bound artifact count; the paper-table experiments above keep
# all four sizes.
ABLATION_MODELS = ["sim-opt-125m", "sim-opt-1.3b"]
ABLATION_EVAL_CONFIGS = [
    "abfp2_w4a4_n64", "abfp2_w4a8_n64",
    "abfp_w4a4_o8_n64", "abfp_w4a4_oe4m3_n64", "abfp_w4a8_o8_n64",
    "mixed_a8_boundary_n64", "mixed_w8a8_boundary_n64",
]


def artifact_defs() -> List[ArtifactDef]:
    defs: List[ArtifactDef] = []
    for name, cfg in MODELS.items():
        if cfg.task == "lm":
            for q in OPT_EVAL_CONFIGS:
                defs.append(ArtifactDef(name, "eval", q))
            if name in ABLATION_MODELS:
                for q in ABLATION_EVAL_CONFIGS:
                    defs.append(ArtifactDef(name, "eval", q))
            defs.append(ArtifactDef(name, "capture", "fp32"))
            for q in OPT_TRAIN_CONFIGS:
                defs.append(ArtifactDef(name, "train", q))
        elif cfg.task == "codegen":
            for q in SMALL_EVAL_CONFIGS:
                defs.append(ArtifactDef(name, "eval_logits", q))
            defs.append(ArtifactDef(name, "train", "fp32"))
        elif cfg.task == "span_qa":
            for q in SMALL_EVAL_CONFIGS:
                defs.append(ArtifactDef(name, "eval", q))
            defs.append(ArtifactDef(name, "train", "fp32"))
        elif cfg.task == "image_cls":
            for q in SMALL_EVAL_CONFIGS:
                defs.append(ArtifactDef(name, "eval", q))
            defs.append(ArtifactDef(name, "train", "fp32"))
    return defs
