"""Quantizer-function combinators — the JAX analog of the paper's §III.

A *quantizer spec* describes one of Eqns (6)/(7)/(9): a coupled
quantize–de-quantize (QDQ) applied to a weight, input-activation or
output tensor while the data stays f32 ("simulated quantization").  The
specs are pure data so the AOT builder can enumerate the artifact matrix
and the manifest can record exactly what each artifact simulates.

Supported kinds:

* ``none``          — identity (FP32/FP16 tensor sites);
* ``abfp``          — dynamic per-vector absmax scaling (Eqn 4), payload
                      in any Format, vectors of length n over the
                      reduction axis — via the Pallas kernel;
* ``abfp2``         — ABFP with *two-level* scales (VS-Quant, §II-B-2):
                      per-vector scales stored as unsigned 8-bit codes
                      against a per-row BF16 second-level scale;
* ``static_int``    — integer QDQ with a *runtime-input* clip range
                      (MSE-calibrated activations; scalar per site);
* ``static_int_pc`` — integer QDQ with a runtime per-channel clip-range
                      vector (RPTQ cluster scales, expressed per-channel);
* ``w_pcmax_int``   — per-output-channel max weight calibration computed
                      in-graph (paper §II-B-1).

For QAT the whole QDQ is wrapped in the Piecewise-Linear estimator
(Eqn 5): d/dx QDQ(x) := 1_{|x| <= alpha}.  With ABFP, alpha is the
per-vector absmax so the mask is all-ones (ABFP never clips) — the
estimator still matters for static quantizers and matches the paper's
training setup.
"""

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from . import formats as F
from .kernels import abfp as abfp_k
from .kernels import intquant as int_k
from .kernels import ref


@dataclass(frozen=True)
class QuantSpec:
    kind: str = "none"  # none | abfp | abfp2 | static_int | static_int_pc | w_pcmax_int
    fmt: Optional[F.Format] = None
    n: int = 64  # ABFP vector length

    @property
    def needs_runtime_scale(self) -> bool:
        return self.kind in ("static_int", "static_int_pc")

    def describe(self) -> dict:
        d = {"kind": self.kind, "n": self.n}
        if self.fmt is not None:
            d["fmt"] = self.fmt.name
        return d


NONE = QuantSpec("none")


def abfp(fmt: F.Format, n: int) -> QuantSpec:
    return QuantSpec("abfp", fmt, n)


def abfp2(fmt: F.Format, n: int) -> QuantSpec:
    return QuantSpec("abfp2", fmt, n)


def static_int(bits: int) -> QuantSpec:
    return QuantSpec("static_int", F.IntFormat(bits))


def static_int_pc(bits: int) -> QuantSpec:
    return QuantSpec("static_int_pc", F.IntFormat(bits))


def w_pcmax_int(bits: int) -> QuantSpec:
    return QuantSpec("w_pcmax_int", F.IntFormat(bits))


def _apply_raw(x, spec: QuantSpec, alpha=None, use_pallas: bool = True):
    """Dispatch a QDQ spec. ``alpha`` is the runtime clip range if needed."""
    if spec.kind == "none":
        return x
    if spec.kind == "abfp":
        fn = abfp_k.abfp_qdq if use_pallas else (
            lambda v, fmt, n: ref.abfp_qdq(v, fmt, n)
        )
        return fn(x, spec.fmt, spec.n)
    if spec.kind == "abfp2":
        fn = abfp_k.abfp2_qdq if use_pallas else (
            lambda v, fmt, n: ref.abfp2_qdq(v, fmt, n)
        )
        return fn(x, spec.fmt, spec.n)
    if spec.kind in ("static_int", "static_int_pc"):
        assert alpha is not None, f"{spec.kind} needs a runtime scale input"
        bits = spec.fmt.bits
        if use_pallas:
            return int_k.static_int_qdq(x, alpha, bits)
        a = jnp.where(alpha > 0, alpha, 1.0)
        qmax = float(2 ** (bits - 1) - 1)
        return ref.int_qdq(x, qmax / a, bits)
    if spec.kind == "w_pcmax_int":
        fn = (
            int_k.per_channel_max_weight_qdq
            if use_pallas
            else ref.per_channel_max_weight_qdq
        )
        return fn(x, spec.fmt.bits)
    raise ValueError(f"unknown quant kind {spec.kind!r}")


# --- PWL straight-through estimator (Eqn 5) -------------------------------
#
# forward:  y = QDQ(x)
# backward: dy/dx = 1_{|x| <= alpha}   (alpha = clip range at each element)


def _clip_range(x, spec: QuantSpec, alpha):
    """Elementwise clip threshold alpha for the PWL mask."""
    if spec.kind in ("abfp", "abfp2"):
        # Per-vector absmax broadcast back over the vector.  Use the RAW
        # absmax (not the BF16-rounded scale): the PWL mask must include
        # the vector's own max element, and BF16 rounding of the scale can
        # land just below it.  (abfp2's ceil-coded scale is >= the raw
        # absmax by construction, so the same mask is exact there too.)
        K = x.shape[-1]
        xb = x.reshape(x.shape[:-1] + (K // spec.n, spec.n))
        a = jnp.max(jnp.abs(xb), axis=-1)
        return jnp.repeat(a, spec.n, axis=-1)
    if spec.kind in ("static_int", "static_int_pc"):
        return jnp.broadcast_to(jnp.where(alpha > 0, alpha, 1.0), x.shape)
    if spec.kind == "w_pcmax_int":
        a = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        return jnp.broadcast_to(jnp.where(a > 0, a, 1.0), x.shape)
    return jnp.full_like(x, jnp.inf)


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(2,))
def _qdq_ste(x, alpha_in, static):
    spec, use_pallas = static
    return _apply_raw(x, spec, alpha_in, use_pallas)


def _qdq_ste_fwd(x, alpha_in, static):
    spec, use_pallas = static
    y = _apply_raw(x, spec, alpha_in, use_pallas)
    mask = (jnp.abs(x) <= _clip_range(x, spec, alpha_in)).astype(x.dtype)
    return y, (mask, jnp.zeros_like(alpha_in))


def _qdq_ste_bwd(static, res, g):
    mask, alpha_zero = res
    return (g * mask, alpha_zero)


_qdq_ste.defvjp(_qdq_ste_fwd, _qdq_ste_bwd)


def apply(
    x,
    spec: QuantSpec,
    alpha=None,
    ste: bool = False,
    use_pallas: bool = True,
):
    """Apply a quantizer spec to ``x``.

    ste=True wraps the QDQ in the PWL estimator for QAT; alpha feeds
    runtime-calibrated clip ranges for the static kinds.
    """
    if spec.kind == "none":
        return x
    if ste:
        a = alpha if alpha is not None else jnp.zeros((), jnp.float32)
        return _qdq_ste(x, a, (spec, use_pallas))
    return _apply_raw(x, spec, alpha, use_pallas)
