"""AOT builder: lower the whole artifact matrix to HLO text + manifest.

Run once at build time (``make artifacts``); Python never appears on the
request path.  Interchange is HLO **text**, not serialized HloModuleProto
— jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under --out (default ../artifacts):
  manifest.json         — models, param layouts, per-artifact I/O specs
  <model>/<id>.hlo.txt  — one compiled-loadable HLO module per artifact
  hashes.json           — config hashes for incremental re-lowering
  goldens/*.json        — quantizer golden tables for the Rust mirrors
"""

import argparse
import hashlib
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import formats as F
from . import quantizers as Q
from . import registry as R
from . import train as T
from .kernels import ref
from .models import bert, common as C, opt, vit

CODE_VERSION = 6  # bump to force re-lowering of every artifact


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs_for(cfg: C.ArchCfg):
    mod = {"opt": opt, "bert": bert, "vit": vit}[cfg.arch]
    return mod.param_specs(cfg)


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def data_inputs(cfg: C.ArchCfg, purpose: str):
    """(name, spec) list for the artifact's data tensors."""
    if cfg.arch == "vit":
        img = f32((cfg.batch, cfg.image, cfg.image, cfg.channels))
        if purpose == "train":
            return [("images", img), ("labels", i32((cfg.batch,)))]
        return [("images", img)]
    toks = i32((cfg.batch, cfg.seq))
    if cfg.arch == "bert" and purpose == "train":
        return [
            ("tokens", toks),
            ("starts", i32((cfg.batch,))),
            ("ends", i32((cfg.batch,))),
        ]
    return [("tokens", toks)]


def quant_inputs(cfg: C.ArchCfg, wiring: C.QuantWiring):
    """(kind, name, spec) for smoothing vectors and static clip ranges."""
    # Per-layer overrides are dynamic-scale (abfp/abfp2) only: static kinds
    # would need per-site alpha inputs this enumerator doesn't emit.
    for _, w in wiring.layer_overrides:
        for spec in (w.wq, w.aq, w.oq):
            assert not spec.needs_runtime_scale, (
                "layer_overrides must use dynamic-scale quantizers"
            )
    out = []
    dims = C.site_dims(cfg)
    names = C.all_site_names(cfg)
    if wiring.smooth:
        for s in names:
            out.append(("smooth", f"smooth.{s}", f32((dims[s],))))
    if wiring.aq.kind == "static_int":
        for s in names:
            out.append(("ascale", f"alpha.{s}", f32(())))
    elif wiring.aq.kind == "static_int_pc":
        for s in names:
            out.append(("ascale", f"alpha.{s}", f32((dims[s],))))
    return out


def build_sites(cfg, wiring, qin_names, qin_vals):
    """Reassemble flat quant inputs into per-site SiteInputs."""
    sites = {}
    for name, val in zip(qin_names, qin_vals):
        kind, site = name.split(".", 1)
        si = sites.setdefault(site, C.SiteInputs())
        if kind == "smooth":
            si.smooth = val
        else:
            si.alpha = val
    return sites


def loss_fn_for(cfg: C.ArchCfg, wiring: C.QuantWiring):
    if cfg.arch == "opt":
        def lm_loss(p, tokens):
            logits = opt.forward(p, tokens, cfg, wiring, {})
            denom = float(cfg.batch * (cfg.seq - 1))
            return opt.nll_sum(logits, tokens) / denom
        return lm_loss
    if cfg.arch == "bert":
        def qa_loss(p, tokens, starts, ends):
            return bert.span_loss(p, tokens, starts, ends, cfg, wiring, {})
        return qa_loss
    def im_loss(p, images, labels):
        return vit.cls_loss(p, images, labels, cfg, wiring, {})
    return im_loss


def build_artifact(adef: R.ArtifactDef):
    """Returns (fn, arg_specs, input_descs, output_descs)."""
    cfg = R.MODELS[adef.model]
    wiring = R.QUANT_CONFIGS[adef.quant]
    pspecs = param_specs_for(cfg)
    pnames = [n for (n, _, _) in pspecs]
    parg = [("param", n, f32(s)) for (n, s, _) in pspecs]
    qarg = [(k, n, s) for (k, n, s) in quant_inputs(cfg, wiring)]
    darg = [("data", n, s) for (n, s) in data_inputs(cfg, adef.purpose)]

    np_, nq, nd = len(parg), len(qarg), len(darg)

    if adef.purpose in ("eval", "eval_logits"):
        inputs = parg + qarg + darg

        def fn(*args):
            p = dict(zip(pnames, args[:np_]))
            qvals = args[np_:np_ + nq]
            sites = build_sites(cfg, wiring, [n for (_, n, _) in qarg], qvals)
            data = args[np_ + nq:]
            if cfg.arch == "opt":
                if adef.purpose == "eval_logits" or cfg.task == "codegen":
                    return opt.eval_logits(p, data[0], cfg, wiring, sites)
                return opt.eval_nll(p, data[0], cfg, wiring, sites)
            if cfg.arch == "bert":
                return bert.eval_spans(p, data[0], cfg, wiring, sites)
            return vit.eval_logits(p, data[0], cfg, wiring, sites)

        if cfg.arch == "opt" and adef.purpose == "eval" and cfg.task != "codegen":
            outs = [("nll_sum", (), "f32")]
        elif cfg.arch == "opt":
            outs = [("logits", (cfg.batch, cfg.seq, cfg.vocab), "f32")]
        elif cfg.arch == "bert":
            outs = [
                ("start_logits", (cfg.batch, cfg.seq), "f32"),
                ("end_logits", (cfg.batch, cfg.seq), "f32"),
            ]
        else:
            outs = [("logits", (cfg.batch, cfg.classes), "f32")]

    elif adef.purpose == "capture":
        inputs = parg + darg

        def fn(*args):
            p = dict(zip(pnames, args[:np_]))
            data = args[np_:]
            mod = {"opt": opt, "bert": bert, "vit": vit}[cfg.arch]
            return mod.capture_acts(p, data[0], cfg)

        ntok = cfg.batch * (cfg.seq if cfg.arch != "vit" else cfg.n_patches + 1)
        dims = C.site_dims(cfg)
        outs = [(s, (ntok, dims[s]), "f32") for s in C.all_site_names(cfg)]
        outs.append(("_anchor", (), "f32"))

    elif adef.purpose == "train":
        marg = [("adam_m", f"m.{n}", f32(s)) for (n, s, _) in pspecs]
        varg = [("adam_v", f"v.{n}", f32(s)) for (n, s, _) in pspecs]
        sarg = [("scalar", "step", f32(())), ("scalar", "lr", f32(()))]
        inputs = parg + marg + varg + sarg + darg
        loss_fn = loss_fn_for(cfg, wiring)
        step_fn = T.make_train_step(loss_fn, pnames)

        def fn(*args):
            P = np_
            plist = list(args[:P])
            mlist = list(args[P:2 * P])
            vlist = list(args[2 * P:3 * P])
            step, lr = args[3 * P], args[3 * P + 1]
            data = args[3 * P + 2:]
            return step_fn(plist, mlist, vlist, step, lr, *data)

        outs = (
            [(f"p.{n}", s, "f32") for (n, s, _) in pspecs]
            + [(f"m.{n}", s, "f32") for (n, s, _) in pspecs]
            + [(f"v.{n}", s, "f32") for (n, s, _) in pspecs]
            + [("loss", (), "f32")]
        )
    else:
        raise ValueError(adef.purpose)

    arg_specs = [s for (_, _, s) in inputs]
    input_descs = [
        {
            "name": n,
            "kind": k,
            "shape": list(s.shape),
            "dtype": "i32" if s.dtype == jnp.int32 else "f32",
        }
        for (k, n, s) in inputs
    ]
    output_descs = [
        {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in outs
    ]
    return fn, arg_specs, input_descs, output_descs


def artifact_hash(adef: R.ArtifactDef) -> str:
    cfg = R.MODELS[adef.model]
    wiring = R.QUANT_CONFIGS[adef.quant]
    key = json.dumps(
        {
            "v": CODE_VERSION,
            "def": [adef.model, adef.purpose, adef.quant],
            "cfg": repr(cfg),
            "wiring": wiring.describe(),
        },
        sort_keys=True,
    )
    return hashlib.sha256(key.encode()).hexdigest()[:16]


# --- goldens ---------------------------------------------------------------


def emit_goldens(outdir: str):
    """Golden tables proving the Rust format mirrors are bit-exact."""
    gdir = os.path.join(outdir, "goldens")
    os.makedirs(gdir, exist_ok=True)
    rs = np.random.RandomState(12345)
    probe = (rs.randn(8, 128) * np.exp(rs.randn(8, 128))).astype(np.float32)
    probe[0, :4] = [0.0, -0.0, 1e-30, -1e30]

    out = {"probe": probe.flatten().tolist()}
    for fmt in (F.E2M1, F.E1M2, F.E4M3):
        out[f"grid_{fmt.name}"] = fmt.grid()
        out[f"fp_round_{fmt.name}"] = (
            np.asarray(ref.fp_round(jnp.asarray(probe), fmt))
            .flatten().tolist()
        )
    for fmt in (F.INT4, F.INT8, F.E2M1, F.E1M2, F.E4M3):
        for n in (64, 128):
            key = f"abfp_{fmt.name}_n{n}"
            out[key] = (
                np.asarray(ref.abfp_qdq(jnp.asarray(probe), fmt, n))
                .flatten().tolist()
            )
    for fmt in (F.INT4, F.INT8, F.E4M3):
        for n in (64, 128):
            key = f"abfp2_{fmt.name}_n{n}"
            out[key] = (
                np.asarray(ref.abfp2_qdq(jnp.asarray(probe), fmt, n))
                .flatten().tolist()
            )
    for bits in (4, 8):
        out[f"static_int{bits}_a2.5"] = (
            np.asarray(ref.static_int_qdq(jnp.asarray(probe), jnp.float32(2.5), bits))
            .flatten().tolist()
        )
        alpha = np.abs(probe).max(axis=0)
        out[f"static_int{bits}_pc"] = (
            np.asarray(ref.static_int_qdq(jnp.asarray(probe), jnp.asarray(alpha), bits))
            .flatten().tolist()
        )
        out[f"pcmax_w_int{bits}"] = (
            np.asarray(ref.per_channel_max_weight_qdq(jnp.asarray(probe), bits))
            .flatten().tolist()
        )
    with open(os.path.join(gdir, "quant_goldens.json"), "w") as f:
        json.dump(out, f)
    print(f"[aot] wrote goldens ({len(out)} tables)")


# --- main ------------------------------------------------------------------


def build_manifest(outdir: str) -> dict:
    models = {}
    for name, cfg in R.MODELS.items():
        pspecs = param_specs_for(cfg)
        dims = C.site_dims(cfg)
        models[name] = {
            "arch": cfg.arch,
            "task": cfg.task,
            "stands_for": cfg.stands_for,
            "vocab": cfg.vocab,
            "d": cfg.d,
            "L": cfg.L,
            "heads": cfg.heads,
            "d_ff": cfg.d_ff,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "image": cfg.image,
            "patch": cfg.patch,
            "channels": cfg.channels,
            "classes": cfg.classes,
            "params": [
                {"name": n, "shape": list(s), "init": init}
                for (n, s, init) in pspecs
            ],
            "sites": [
                {"name": s, "dim": dims[s]} for s in C.all_site_names(cfg)
            ],
        }
    return {"version": 1, "models": models, "artifacts": {}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="regex filter on artifact ids")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--goldens-only", action="store_true")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    emit_goldens(outdir)
    if args.goldens_only:
        return

    hpath = os.path.join(outdir, "hashes.json")
    hashes = {}
    if os.path.exists(hpath) and not args.force:
        with open(hpath) as f:
            hashes = json.load(f)

    manifest = build_manifest(outdir)
    defs = R.artifact_defs()
    if args.only:
        pat = re.compile(args.only)
        keep = [d for d in defs if pat.search(d.id)]
    else:
        keep = defs

    t0 = time.time()
    n_lowered = 0
    for i, adef in enumerate(keep):
        fn, arg_specs, input_descs, output_descs = build_artifact(adef)
        rel = f"{adef.model}/{adef.purpose}_{adef.quant}.hlo.txt"
        path = os.path.join(outdir, rel)
        h = artifact_hash(adef)
        manifest["artifacts"][adef.id] = {
            "file": rel,
            "model": adef.model,
            "purpose": adef.purpose,
            "quant": adef.quant,
            "wiring": R.QUANT_CONFIGS[adef.quant].describe(),
            "inputs": input_descs,
            "outputs": output_descs,
        }
        if hashes.get(adef.id) == h and os.path.exists(path):
            continue
        os.makedirs(os.path.dirname(path), exist_ok=True)
        t1 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        hashes[adef.id] = h
        n_lowered += 1
        print(
            f"[aot] ({i + 1}/{len(keep)}) {adef.id}: "
            f"{len(text) / 1024:.0f} KiB in {time.time() - t1:.1f}s"
        )
        # Persist hashes incrementally so an interrupted run resumes.
        with open(hpath, "w") as f:
            json.dump(hashes, f)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"[aot] done: {n_lowered} lowered, {len(keep) - n_lowered} cached, "
        f"{time.time() - t0:.1f}s total"
    )


if __name__ == "__main__":
    main()
